//! §Perf serving bench: capacity and tail latency of the coordinator as a
//! function of micro-batch size and worker count.
//!
//! Drives the serving pipeline **closed-loop** (issue-on-completion, a
//! full pipeline of `2 * workers * max_batch` outstanding requests) so
//! the measured rps is service capacity, not arrival-rate replay. Uses
//! the real cnn10 artifacts when `make artifacts` has run, otherwise a
//! synthetic cnn10-scale bundle — the emitted `BENCH_serving.json`
//! (override the path with `MOR_BENCH_SERVING_OUT`) is always complete
//! and machine-diffable across PRs.
//!
//! A second section replays the sharded serving tier's canonical
//! overload scenario on the **virtual clock** (`ServingTier::simulate`):
//! two models, two weighted tenants, a 20 ms deadline, work stealing.
//! Those numbers are deterministic — identical on every machine — so
//! the `serving_tier` block of `BENCH_serving.json` diffs exactly
//! across PRs, including its per-tenant and per-model breakdowns.
mod common;

use mor::config::PredictorConfig;
use mor::coordinator::tier::{ServingTier, VirtualService};
use mor::coordinator::{serve, Backend, GroupStats, ServeOpts};
use mor::model::{synth, Artifacts};
use mor::session::Session;
use mor::workload::{merge, Arrival, RequestStream};

const WORKERS: [usize; 2] = [1, 4];
const BATCHES: [usize; 4] = [1, 4, 8, 16];
const REQUESTS_PER_CONFIG: usize = 192;

fn workload() -> (Artifacts, String) {
    if let Some(zoo) = common::load_zoo() {
        if let Some(a) = zoo.into_iter().find(|a| a.meta.name == "cnn10") {
            return (a, "cnn10".to_string());
        }
    }
    // synthetic fallback: cnn10-scale model, self-consistent labels
    (
        synth::artifacts_for(synth::cnn10_like(21), 22, 64, 4),
        "cnn10-synth".to_string(),
    )
}

fn main() {
    let (arts, label) = workload();
    println!("serving bench on {label}: closed loop, {REQUESTS_PER_CONFIG} requests per config");

    // one session for the whole sweep: model cloned and prepacked once,
    // policy prepared once, shared read-only by every worker config
    let session = Session::from_artifacts(
        &arts,
        PredictorConfig { threshold: 0.5, ..Default::default() },
    );
    let mut rows: Vec<String> = Vec::new();
    for &workers in &WORKERS {
        for &max_batch in &BATCHES {
            // arrival times are ignored in closed loop; the stream only
            // supplies ids + sample indices
            let mut stream = RequestStream::new(1000.0, arts.data.n_test(), 42);
            let mut requests = stream.generate(10.0);
            requests.truncate(REQUESTS_PER_CONFIG);
            let n = requests.len();
            let rep = serve(
                &arts,
                &session,
                Backend::Engine,
                requests,
                "unused",
                ServeOpts {
                    workers,
                    max_batch,
                    batch_wait_us: 500,
                    closed_loop: true,
                    concurrency: 2 * workers * max_batch,
                    ..Default::default()
                },
            )
            .expect("serve");
            assert_eq!(rep.completed, n, "bench dropped requests");
            println!(
                "  workers={workers} batch<={max_batch:<2} → {:>7.1} rps | occupancy {:>5.2} | \
                 p50 {:>7.2} ms p99 {:>7.2} ms",
                rep.throughput_rps, rep.batch_occupancy, rep.p50_ms, rep.p99_ms
            );
            rows.push(format!(
                "    {{\"workers\": {workers}, \"max_batch\": {max_batch}, \
                 \"predictor\": \"{}\", \
                 \"rps\": {:.2}, \"p50_ms\": {:.3}, \"p99_ms\": {:.3}, \
                 \"mean_service_ms\": {:.3}, \"batch_occupancy\": {:.3}, \
                 \"dropped\": {}}}",
                rep.predictor,
                rep.throughput_rps,
                rep.p50_ms,
                rep.p99_ms,
                rep.mean_service_ms,
                rep.batch_occupancy,
                rep.dropped
            ));
        }
    }

    let tier_js = tier_section(&arts, &session);

    let out_path = std::env::var("MOR_BENCH_SERVING_OUT")
        .unwrap_or_else(|_| "BENCH_serving.json".to_string());
    let mut js = String::new();
    js.push_str("{\n");
    js.push_str("  \"bench\": \"perf_serving\",\n");
    js.push_str(&common::provenance_json());
    js.push_str(&format!("  \"model\": \"{label}\",\n"));
    js.push_str(&format!("  \"predictor\": \"{}\",\n", session.predictor_name()));
    js.push_str(&format!("  \"requests_per_config\": {REQUESTS_PER_CONFIG},\n"));
    js.push_str(&format!(
        "  \"threads_available\": {},\n",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    ));
    js.push_str("  \"mode\": \"closed_loop\",\n");
    js.push_str("  \"configs\": [\n");
    js.push_str(&rows.join(",\n"));
    js.push_str("\n  ],\n");
    js.push_str(&tier_js);
    js.push_str("}\n");
    match std::fs::write(&out_path, &js) {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => eprintln!("\nfailed to write {out_path}: {e}"),
    }
}

/// The tier's canonical overload scenario on the virtual clock: model
/// "hot" takes 5 000 rps (2 500 each from tenants gold:2 and free:1)
/// against a 2-replica, 1 ms/request capacity of 2 000 rps; model
/// "cold" idles at 500 rps, lending its spare replicas through work
/// stealing. Deadline 20 ms. Returns the `"serving_tier": {...}` JSON
/// fragment (trailing newline, no trailing comma).
fn tier_section(arts: &Artifacts, session: &Session) -> String {
    const SVC_US: u64 = 1000;
    const DEADLINE_MS: f64 = 20.0;
    let tier = ServingTier::builder()
        .model("hot", arts, session, 2)
        .model("cold", arts, session, 2)
        .tenant("gold", 2)
        .tenant("free", 1)
        .deadline_ms(DEADLINE_MS)
        .finish();
    let steady = |rate: f64, tenant: usize, seed: u64| {
        let mut s = RequestStream::with_arrival(
            Arrival::Steady { rate_per_s: rate },
            arts.data.n_test(),
            seed,
        )
        .for_tenant(tenant);
        s.generate(1.0)
    };
    let traces = vec![
        merge(vec![steady(2500.0, 0, 81), steady(2500.0, 1, 82)]),
        steady(500.0, 0, 83),
    ];
    let rep = tier
        .simulate(traces, &VirtualService { svc_us: vec![SVC_US, SVC_US], execute: false })
        .expect("tier simulate");
    assert!(rep.conserved(), "tier bench lost requests");
    println!(
        "\nserving tier (virtual clock): {} submitted → {} completed, {} shed \
         | goodput {:.0} rps | p99 {:.2} ms",
        rep.submitted, rep.completed, rep.shed, rep.goodput_rps, rep.p99_ms
    );

    let group = |g: &GroupStats| {
        format!(
            "      {{\"name\": \"{}\", \"submitted\": {}, \"completed\": {}, \
             \"shed\": {}, \"goodput_rps\": {:.2}, \"p50_ms\": {:.3}, \"p99_ms\": {:.3}}}",
            g.name, g.submitted, g.completed, g.shed, g.goodput_rps, g.p50_ms, g.p99_ms
        )
    };
    let tenants: Vec<String> = rep.per_tenant.iter().map(&group).collect();
    let models: Vec<String> = rep.per_model.iter().map(&group).collect();
    format!(
        "  \"serving_tier\": {{\n\
         \x20   \"scenario\": \"hot 5000 rps (gold:2 + free:1) vs cold 500 rps, \
         2 replicas/model, 1 ms/request, deadline 20 ms, stealing on\",\n\
         \x20   \"deadline_ms\": {DEADLINE_MS:.1},\n\
         \x20   \"svc_us\": {SVC_US},\n\
         \x20   \"replicas\": 2,\n\
         \x20   \"steal\": true,\n\
         \x20   \"submitted\": {},\n\
         \x20   \"completed\": {},\n\
         \x20   \"dropped\": {},\n\
         \x20   \"shed\": {},\n\
         \x20   \"shed_admission\": {},\n\
         \x20   \"shed_expired\": {},\n\
         \x20   \"throughput_rps\": {:.2},\n\
         \x20   \"goodput_rps\": {:.2},\n\
         \x20   \"p50_ms\": {:.3},\n\
         \x20   \"p99_ms\": {:.3},\n\
         \x20   \"max_queue_depth\": {},\n\
         \x20   \"per_tenant\": [\n{}\n    ],\n\
         \x20   \"per_model\": [\n{}\n    ]\n\
         \x20 }}\n",
        rep.submitted,
        rep.completed,
        rep.dropped,
        rep.shed,
        rep.shed_admission,
        rep.shed_expired,
        rep.throughput_rps,
        rep.goodput_rps,
        rep.p50_ms,
        rep.p99_ms,
        rep.max_queue_depth,
        tenants.join(",\n"),
        models.join(",\n")
    )
}
