//! Shared scaffolding for the figure benches: artifact discovery + a
//! skip-gracefully path when `make artifacts` has not run yet (cargo bench
//! must not hard-fail on a fresh checkout).
#![allow(dead_code)]

use mor::model::Artifacts;

pub fn artifacts_dir() -> String {
    std::env::var("MOR_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
}

/// Load the full model zoo, or None (with a notice) when artifacts are absent.
pub fn load_zoo() -> Option<Vec<Artifacts>> {
    let dir = artifacts_dir();
    match mor::figures::load_all(&dir) {
        Ok(a) => Some(a),
        Err(e) => {
            println!("SKIP: artifacts not available ({e}); run `make artifacts` first");
            None
        }
    }
}

pub fn out_dir() -> String {
    std::env::var("MOR_FIGURES_OUT").unwrap_or_else(|_| "figures_out".to_string())
}

/// The `_provenance` line every `BENCH_*.json` carries: which ISA tiers
/// the host detected and dispatched, and the content hash of the tune
/// profile the run defaulted to — so perf trajectories are only diffed
/// between like configurations. Returns a full `"_provenance": {...},`
/// line (two-space indent, trailing comma + newline).
pub fn provenance_json() -> String {
    use mor::engine::{isa, tune::TuneProfile};
    format!(
        "  \"_provenance\": {{\"isa_detected\": \"{}\", \"isa_active\": \"{}\", \
         \"tune_profile_hash\": \"{:016x}\"}},\n",
        isa::detected().name(),
        isa::active().name(),
        TuneProfile::host_default().hash()
    )
}
