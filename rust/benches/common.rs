//! Shared scaffolding for the figure benches: artifact discovery + a
//! skip-gracefully path when `make artifacts` has not run yet (cargo bench
//! must not hard-fail on a fresh checkout).
#![allow(dead_code)]

use mor::model::Artifacts;

pub fn artifacts_dir() -> String {
    std::env::var("MOR_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
}

/// Load the full model zoo, or None (with a notice) when artifacts are absent.
pub fn load_zoo() -> Option<Vec<Artifacts>> {
    let dir = artifacts_dir();
    match mor::figures::load_all(&dir) {
        Ok(a) => Some(a),
        Err(e) => {
            println!("SKIP: artifacts not available ({e}); run `make artifacts` first");
            None
        }
    }
}

pub fn out_dir() -> String {
    std::env::var("MOR_FIGURES_OUT").unwrap_or_else(|_| "figures_out".to_string())
}
