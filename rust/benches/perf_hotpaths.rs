//! §Perf micro-benchmarks: the three host hot paths (dot kernel, packed
//! binary dot, full MoR forward) tracked across the optimization pass.
mod common;
use mor::engine::dot::dot_i8;
use mor::util::bench::bench_with;
use mor::util::bits::PackedVec;
use mor::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(7);
    let k = 576usize;
    let x: Vec<i8> = (0..k).map(|_| rng.int8()).collect();
    let w: Vec<i8> = (0..k).map(|_| rng.int8()).collect();

    let t = bench_with("dot_i8 (K=576)", 10, 0.3, &mut || {
        std::hint::black_box(dot_i8(std::hint::black_box(&x), std::hint::black_box(&w)));
    });
    t.report();
    let gmacs = k as f64 / t.min_ns;
    println!("    ≈ {gmacs:.2} GMAC/s single-thread (min)");

    let px = PackedVec::from_acts(&x);
    let pw = PackedVec::from_weights(&w);
    let t = bench_with("packed binary dot (K=576)", 10, 0.3, &mut || {
        std::hint::black_box(px.dot(std::hint::black_box(&pw)));
    });
    t.report();

    if let Some(zoo) = common::load_zoo() {
        for a in zoo.iter().filter(|a| a.meta.name == "cnn10") {
            let pol = mor::predictor::MorPolicy::new(
                &a.model, &a.predictor, Default::default());
            let xs = a.data.test_sample(0).to_vec();
            let t = bench_with("cnn10 MoR fwd (oracle off)", 1, 0.5, &mut || {
                std::hint::black_box(mor::predictor::exec::run_sample(
                    &a.model, Some(&pol), &xs,
                    mor::predictor::RunOpts { oracle: false, collect_trace: false }));
            });
            t.report();
            let macs = a.meta.macs_per_sample as f64;
            println!("    ≈ {:.2} effective GMAC/s", macs / t.min_ns);
        }
    }
}
