//! §Perf micro-benchmarks: the host hot paths tracked across the
//! optimization passes — dot kernels (dense, input-sparse and
//! doubly-sparse), the scalar GEMV vs tiled GEMM engine, the full MoR
//! forward at 1/2/4/8 row-tile threads, the input-sparsity modes
//! (§Sparse), the weight-sparsity modes on a pruned model
//! (§Weights, triple-sided MAC split), and the plan/workspace
//! steady-state path (§Plan): cached-plan forward vs per-call compile +
//! fresh workspace, with an asserted zero-allocations-per-request count
//! and the workspace footprint.
//!
//! Besides the human-readable report, emits `BENCH_hotpaths.json`
//! (override the path with `MOR_BENCH_OUT`) so the perf trajectory is
//! machine-diffable across PRs. Falls back to a synthetic cnn10-scale
//! model when `make artifacts` has not run, so the JSON is always
//! complete.
mod common;

use mor::config::PredictorConfig;
use mor::engine::dot::{dot_i8, dot_i8_sparse, dot_i8_sparse_sparse};
use mor::engine::gemm::{self, PrepackedFilters, NR};
use mor::engine::isa;
use mor::engine::tune;
use mor::engine::{crossover, WeightSparsity};
use mor::model::synth;
use mor::predictor::strategies::{Strategy, ZeroPredictor};
use mor::predictor::{exec, EngineSel, InputSparsity, OpsStats, RunOpts};
use mor::session::Session;
use mor::util::alloc_count::{allocs_on_this_thread, CountingAlloc};
use mor::util::bench::{bench_with, Timing};
use mor::util::bits::PackedVec;
use mor::util::rng::Rng;
use std::hint::black_box;

// Per-thread allocation counter (mor::util::alloc_count): the §Plan
// section asserts the planned forward's steady state allocates nothing.
#[global_allocator]
static COUNTING: CountingAlloc = CountingAlloc;

const FWD_THREADS: [usize; 4] = [1, 2, 4, 8];
/// Thread counts for the per-strategy predict-overhead matrix.
const STRATEGY_THREADS: [usize; 3] = [1, 4, 8];
/// Strategies compared in `BENCH_predictors.json` (`oracle` is excluded:
/// its host-side decision cost models no hardware). `none` runs first —
/// it is the denominator the others' overhead is measured against.
const STRATEGIES: [Strategy; 4] =
    [Strategy::None, Strategy::Mor, Strategy::Binary, Strategy::Cluster];

fn main() {
    let mut rng = Rng::new(7);
    let k = 576usize; // largest K in the model zoo (3x3x64)
    let cout = 64usize;
    let rows = 64usize;
    let x: Vec<i8> = (0..k).map(|_| rng.int8()).collect();
    let w: Vec<i8> = (0..k).map(|_| rng.int8()).collect();

    // ---- single-dot kernels ---------------------------------------------
    let t_dot = bench_with("dot_i8 (K=576)", 10, 0.2, &mut || {
        black_box(dot_i8(black_box(&x), black_box(&w)));
    });
    t_dot.report();
    let dot_gmacs = k as f64 / t_dot.min_ns;
    println!("    ≈ {dot_gmacs:.2} GMAC/s single-thread (min)");

    let px = PackedVec::from_acts(&x);
    let pw = PackedVec::from_weights(&w);
    let t_bin = bench_with("packed binary dot (K=576)", 10, 0.2, &mut || {
        black_box(px.dot(black_box(&pw)));
    });
    t_bin.report();
    let bin_gops = k as f64 / t_bin.min_ns;

    // ---- sparse dot kernel at a few input densities ---------------------
    // effective GMAC/s counts the full K: the sparse kernel's win is doing
    // the same logical dot while touching only the nonzero lanes
    for density_pct in [10usize, 25, 50] {
        let xs_sparse: Vec<i8> = x
            .iter()
            .enumerate()
            .map(|(i, &v)| if (i * 97) % 100 < density_pct { v } else { 0 })
            .collect();
        let (mut idx, mut val) = (Vec::new(), Vec::new());
        for (i, &v) in xs_sparse.iter().enumerate() {
            if v != 0 {
                idx.push(i as u16);
                val.push(v);
            }
        }
        let t_sp = bench_with(
            &format!("dot_i8_sparse (K=576, {density_pct}% dense)"),
            10,
            0.2,
            &mut || {
                black_box(dot_i8_sparse(black_box(&idx), black_box(&val), black_box(&w)));
            },
        );
        t_sp.report();
        println!(
            "    ≈ {:.2} effective GMAC/s ({:.2}x vs dense dot)",
            k as f64 / t_sp.min_ns,
            t_dot.min_ns / t_sp.min_ns
        );
    }

    // ---- per-ISA dot kernels (§ISA) -------------------------------------
    // the same K=576 dot forced down every tier this host can run, at a
    // density sweep. The dense kernels are density-invariant by design
    // (the i32-dot contract trades no correctness for sparsity), so flat
    // rows here are the expected shape — the columns give the sparse-dot
    // trajectories above a per-ISA dense baseline at matching shapes.
    // This bench binary is single-threaded, so the process-global
    // forced-ISA override is safe to sweep here.
    println!("\nper-ISA dot kernels:");
    let mut isa_dot: Vec<(&'static str, Vec<(usize, f64)>)> = Vec::new();
    for tier in isa::available() {
        isa::force(Some(tier));
        let mut pts = Vec::new();
        for density_pct in [10usize, 25, 50, 100] {
            let xd: Vec<i8> = x
                .iter()
                .enumerate()
                .map(|(i, &v)| if (i * 97) % 100 < density_pct { v } else { 0 })
                .collect();
            let t = bench_with(
                &format!("dot_i8 [{}] (K=576, {density_pct}% dense)", tier.name()),
                10,
                0.2,
                &mut || {
                    black_box(dot_i8(black_box(&xd), black_box(&w)));
                },
            );
            t.report();
            pts.push((density_pct, k as f64 / t.min_ns));
        }
        isa_dot.push((tier.name(), pts));
    }
    isa::force(None);

    // ---- scalar GEMV vs tiled GEMM on one dense layer -------------------
    let node = synth::dense_node(k, cout, 11);
    let pf = PrepackedFilters::new(&node);
    let patches: Vec<Vec<i8>> = (0..rows)
        .map(|_| (0..k).map(|_| rng.int8()).collect())
        .collect();
    let mut padded = vec![0i8; rows * pf.k_pad];
    for (r, p) in patches.iter().enumerate() {
        padded[r * pf.k_pad..r * pf.k_pad + k].copy_from_slice(p);
    }
    let work_macs = (rows * cout * k) as f64;

    let mut sink = 0i64;
    let t_gemv = bench_with("per-neuron GEMV (64 rows x 64 filters)", 3, 0.3, &mut || {
        let mut acc = 0i64;
        for p in &patches {
            for f in 0..cout {
                acc += dot_i8(p, node.filter(f)) as i64;
            }
        }
        sink ^= black_box(acc);
    });
    t_gemv.report();
    let gemv_gmacs = work_macs / t_gemv.min_ns;
    println!("    ≈ {gemv_gmacs:.2} GMAC/s");

    let t_gemm = bench_with("tiled GEMM micro-kernel (same work)", 3, 0.3, &mut || {
        let mut acc = 0i64;
        let mut blk = [0i32; NR];
        let mut f0 = 0;
        while f0 < cout {
            let nf = NR.min(cout - f0);
            for r in 0..rows {
                gemm::dot_block(&padded[r * pf.k_pad..(r + 1) * pf.k_pad], &pf, f0, nf, &mut blk);
                for &d in &blk[..nf] {
                    acc += d as i64;
                }
            }
            f0 += NR;
        }
        sink ^= black_box(acc);
    });
    t_gemm.report();
    let gemm_gmacs = work_macs / t_gemm.min_ns;
    println!(
        "    ≈ {gemm_gmacs:.2} GMAC/s ({:.2}x over per-neuron GEMV)",
        t_gemv.min_ns / t_gemm.min_ns
    );
    black_box(sink);

    // ---- full MoR forward: scalar reference vs tiled at 1/2/4/8 threads -
    let (arts, xs, thr, model_label) = forward_workload();
    let session = Session::from_artifacts(
        &arts,
        PredictorConfig { threshold: thr, ..Default::default() },
    );
    println!("\nfull MoR forward on {model_label}:");
    let scalar_opts = RunOpts {
        oracle: false,
        collect_trace: false,
        threads: 1,
        engine: EngineSel::ScalarRef,
        ..Default::default()
    };
    let scalar_sess = session.with_opts(scalar_opts);
    let t_scalar = bench_with(
        &format!("{model_label} MoR fwd, per-neuron baseline"),
        1,
        0.5,
        &mut || {
            black_box(scalar_sess.run_sample(&xs));
        },
    );
    t_scalar.report();

    let mut tiled: Vec<(usize, Timing)> = Vec::new();
    for threads in FWD_THREADS {
        let sess =
            session.with_opts(RunOpts { threads, engine: EngineSel::Tiled, ..scalar_opts });
        let t = bench_with(
            &format!("{model_label} MoR fwd, tiled GEMM, {threads} thread(s)"),
            1,
            0.5,
            &mut || {
                black_box(sess.run_sample(&xs));
            },
        );
        t.report();
        tiled.push((threads, t));
    }
    let t1 = tiled[0].1.min_ns;
    println!(
        "    single-thread speedup vs per-neuron: {:.2}x | 4-thread scaling: {:.2}x over 1-thread",
        t_scalar.min_ns / t1,
        t1 / tiled.iter().find(|(n, _)| *n == 4).map(|(_, t)| t.min_ns).unwrap_or(t1)
    );

    // ---- autotuned vs default forward (§Tune) ---------------------------
    // calibrate this host, freeze the fitted profile into a derived
    // session, and compare against the compiled-in defaults. Logits are
    // asserted bit-identical first: the profile is a pure host-perf knob.
    let tuned_profile = tune::calibrate();
    println!(
        "\nautotune on {model_label}: isa {} | input_cutoff {:.3} | weight_cutoff {:.3} \
         | tile_rows {} | threads {} | hash {:016x}",
        tuned_profile.isa.name(),
        tuned_profile.input_cutoff,
        tuned_profile.weight_cutoff,
        tuned_profile.tile_rows,
        tuned_profile.threads,
        tuned_profile.hash()
    );
    let tuned_sess = session.with_opts(RunOpts {
        threads: tuned_profile.threads.max(1),
        engine: EngineSel::Tiled,
        tune: tuned_profile,
        ..scalar_opts
    });
    let default_logits = session
        .with_opts(RunOpts { threads: 1, engine: EngineSel::Tiled, ..scalar_opts })
        .run_sample(&xs)
        .logits;
    assert_eq!(
        default_logits,
        tuned_sess.run_sample(&xs).logits,
        "tune profile changed logits — the i32-dot contract is broken"
    );
    let t_tuned = bench_with(
        &format!("{model_label} MoR fwd, autotuned profile"),
        1,
        0.5,
        &mut || {
            black_box(tuned_sess.run_sample(&xs));
        },
    );
    t_tuned.report();
    println!("    vs 1-thread default: {:.2}x", t1 / t_tuned.min_ns);

    // ---- input sparsity (§Sparse) ----------------------------------------
    // same forward, three kernel modes; results are bit-identical, so the
    // stats come from one run and only wall-clock differs
    println!("\ninput sparsity on {model_label}:");
    let sp_base = RunOpts {
        oracle: false,
        collect_trace: false,
        threads: 1,
        engine: EngineSel::Tiled,
        input_sparsity: InputSparsity::Off,
        weight_sparsity: WeightSparsity::Off,
    };
    let sp_ops: OpsStats = session.with_opts(sp_base).run_sample(&xs).ops;
    let mut sparse_ms: Vec<(&str, f64)> = Vec::new();
    for (label, mode) in [
        ("off", InputSparsity::Off),
        ("auto", InputSparsity::Auto),
        ("on", InputSparsity::On),
    ] {
        let sess = session.with_opts(RunOpts { input_sparsity: mode, ..sp_base });
        let r = sess.run_sample(&xs);
        assert_eq!(r.ops, sp_ops, "input-sparsity mode changed OpsStats");
        let t = bench_with(
            &format!("{model_label} MoR fwd, --input-sparsity {label}"),
            1,
            0.3,
            &mut || {
                black_box(sess.run_sample(black_box(&xs)));
            },
        );
        t.report();
        sparse_ms.push((label, t.min_ns / 1e6));
    }
    println!(
        "    output-pred saved {:.1}% of total MACs | input-zero {:.1}% of done MACs \
         | auto cutoff {:.2}",
        sp_ops.macs_saved_frac() * 100.0,
        sp_ops.input_zero_frac() * 100.0,
        gemm::sparse_auto_cutoff()
    );

    // ---- triple-sided weight sparsity (§Weights) ------------------------
    // (a) the doubly-sparse index-intersection dot at a few weight
    // densities (x fixed at 25% dense, matching a post-ReLU activation);
    // effective GMAC/s counts the full K, like the input-sparse kernel
    println!("\nweight sparsity (triple-sided):");
    let (x_idx, x_val): (Vec<u16>, Vec<i8>) = {
        let (mut idx, mut val) = (Vec::new(), Vec::new());
        for (i, &v) in x.iter().enumerate() {
            if (i * 97) % 100 < 25 && v != 0 {
                idx.push(i as u16);
                val.push(v);
            }
        }
        (idx, val)
    };
    let mut ss_gmacs: Vec<(usize, f64)> = Vec::new();
    for density_pct in [10usize, 25, 50] {
        let (mut w_idx, mut w_val) = (Vec::new(), Vec::new());
        for (i, &v) in w.iter().enumerate() {
            if (i * 89) % 100 < density_pct && v != 0 {
                w_idx.push(i as u16);
                w_val.push(v);
            }
        }
        let t_ss = bench_with(
            &format!("dot_i8_sparse_sparse (K=576, w {density_pct}% dense, x 25%)"),
            10,
            0.2,
            &mut || {
                black_box(dot_i8_sparse_sparse(
                    black_box(&x_idx),
                    black_box(&x_val),
                    black_box(&w_idx),
                    black_box(&w_val),
                ));
            },
        );
        t_ss.report();
        let g = k as f64 / t_ss.min_ns;
        println!("    ≈ {g:.2} effective GMAC/s ({:.2}x vs dense dot)", t_dot.min_ns / t_ss.min_ns);
        ss_gmacs.push((density_pct, g));
    }

    // (b) full forward per weight-sparsity mode on a pruned clone of the
    // workload model (90% zeroed: well past the ≥30%-zero target and
    // below the crossover on every host, so `exact` swaps kernels);
    // results are bit-identical, so the triple-sided split comes from
    // one run and only wall-clock differs
    let mut wmodel = arts.model.clone();
    synth::sparsify_weights(&mut wmodel, 31, 90);
    let w_zero_frac = wmodel.weight_zero_fraction();
    let wsession = Session::build(&wmodel)
        .params(&arts.predictor)
        .threshold(thr)
        .finish();
    let w_ops: OpsStats = wsession.with_opts(sp_base).run_sample(&xs).ops;
    let mut weight_ms: Vec<(&str, f64)> = Vec::new();
    for (label, mode) in [("off", WeightSparsity::Off), ("exact", WeightSparsity::Exact)] {
        let sess = wsession.with_opts(RunOpts { weight_sparsity: mode, ..sp_base });
        let r = sess.run_sample(&xs);
        assert_eq!(r.ops, w_ops, "weight-sparsity mode changed OpsStats");
        let t = bench_with(
            &format!("{model_label} (90% zero wt) MoR fwd, --weight-sparsity {label}"),
            1,
            0.3,
            &mut || {
                black_box(sess.run_sample(black_box(&xs)));
            },
        );
        t.report();
        weight_ms.push((label, t.min_ns / 1e6));
    }
    println!(
        "    weight-zero {:.1}% of done MACs | input-zero {:.1}% | output-pred saved {:.1}% \
         of total | weight cutoff {:.2}",
        w_ops.weight_zero_frac() * 100.0,
        w_ops.input_zero_frac() * 100.0,
        w_ops.macs_saved_frac() * 100.0,
        crossover::weight_sparse_cutoff()
    );

    // ---- plan & workspace steady state (§Plan) --------------------------
    // cached-plan + pooled-workspace forward (what a Session serves with)
    // vs the per-call path (plan compiled and workspace allocated per
    // request — what the free exec::run_batch functions do)
    println!("\nplan & workspace on {model_label}:");
    let mut plan_ms: Vec<(usize, f64, f64)> = Vec::new();
    for threads in [1usize, 4, 8] {
        let sess = session.with_opts(RunOpts {
            oracle: false,
            collect_trace: false,
            threads,
            engine: EngineSel::Tiled,
            ..Default::default()
        });
        let mut ws = sess.checkout_workspace();
        let mut results = Vec::new();
        sess.run_batch_into(&mut ws, &[xs.as_slice()], &mut results); // warmup
        let t_planned = bench_with(
            &format!("{model_label} planned fwd (cached plan + workspace), {threads} thread(s)"),
            1,
            0.3,
            &mut || {
                sess.run_batch_into(&mut ws, &[xs.as_slice()], &mut results);
                black_box(&results);
            },
        );
        t_planned.report();
        let t_percall = bench_with(
            &format!("{model_label} per-call fwd (compile + fresh workspace), {threads} thread(s)"),
            1,
            0.3,
            &mut || {
                black_box(exec::run_batch(
                    sess.model(),
                    sess.policy(),
                    &[xs.as_slice()],
                    sess.opts(),
                ));
            },
        );
        t_percall.report();
        println!(
            "    per-request setup overhead removed: {:.2}x",
            t_percall.min_ns / t_planned.min_ns
        );
        plan_ms.push((threads, t_planned.min_ns / 1e6, t_percall.min_ns / 1e6));
    }
    // allocations per request after warmup (serving worker config:
    // 1 thread, no tracing) — the steady state must allocate NOTHING.
    // A fresh (non-pooled) workspace, so the reported footprint is one
    // 1-thread worker's, not a pool-recycled 8-thread workspace's
    let (allocs_per_request, ws_bytes_per_worker) = {
        let sess = session.with_opts(RunOpts {
            oracle: false,
            collect_trace: false,
            threads: 1,
            engine: EngineSel::Tiled,
            ..Default::default()
        });
        let mut ws = mor::plan::Workspace::new();
        let mut results = Vec::new();
        sess.run_batch_into(&mut ws, &[xs.as_slice()], &mut results);
        sess.run_batch_into(&mut ws, &[xs.as_slice()], &mut results);
        let n_reqs = 32u64;
        let before = allocs_on_this_thread();
        for _ in 0..n_reqs {
            sess.run_batch_into(&mut ws, &[xs.as_slice()], &mut results);
        }
        let per_req = (allocs_on_this_thread() - before) as f64 / n_reqs as f64;
        assert_eq!(
            per_req, 0.0,
            "steady-state planned forward must make zero heap allocations"
        );
        (per_req, ws.heap_bytes())
    };
    println!(
        "    allocations/request after warmup: {allocs_per_request:.1} | \
         workspace {:.1} KiB per worker",
        ws_bytes_per_worker as f64 / 1024.0
    );

    // ---- machine-readable trajectory ------------------------------------
    let out_path =
        std::env::var("MOR_BENCH_OUT").unwrap_or_else(|_| "BENCH_hotpaths.json".to_string());
    let mut js = String::new();
    js.push_str("{\n");
    js.push_str("  \"bench\": \"perf_hotpaths\",\n");
    js.push_str(&common::provenance_json());
    js.push_str(&format!(
        "  \"threads_available\": {},\n",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    ));
    // per-ISA dot throughput plus what the calibrated profile buys on
    // the full forward — the cross-host kernel trajectory
    js.push_str("  \"kernels\": {\n");
    js.push_str("    \"dot_gmacs\": {");
    for (i, (tier, pts)) in isa_dot.iter().enumerate() {
        if i > 0 {
            js.push_str(", ");
        }
        js.push_str(&format!("\"{tier}\": {{"));
        for (j, (d, g)) in pts.iter().enumerate() {
            if j > 0 {
                js.push_str(", ");
            }
            js.push_str(&format!("\"{d}\": {g:.4}"));
        }
        js.push('}');
    }
    js.push_str("},\n");
    js.push_str(&format!(
        "    \"tuned_profile_hash\": \"{:016x}\",\n",
        tuned_profile.hash()
    ));
    js.push_str(&format!(
        "    \"forward_ms\": {{\"default\": {:.4}, \"tuned\": {:.4}}}\n",
        t1 / 1e6,
        t_tuned.min_ns / 1e6
    ));
    js.push_str("  },\n");
    js.push_str(&format!("  \"dot_i8_gmacs\": {dot_gmacs:.4},\n"));
    js.push_str(&format!("  \"packed_bin_dot_gops\": {bin_gops:.4},\n"));
    js.push_str(&format!("  \"gemv_scalar_gmacs\": {gemv_gmacs:.4},\n"));
    js.push_str(&format!("  \"gemm_tiled_gmacs\": {gemm_gmacs:.4},\n"));
    js.push_str(&format!(
        "  \"gemm_vs_gemv_speedup\": {:.4},\n",
        t_gemv.min_ns / t_gemm.min_ns
    ));
    // input-side accounting: output-prediction savings vs input-zero
    // (ineffectual) MACs, plus per-mode forward wall-clock (the full
    // triple-sided split lives in the weight_sparsity object below)
    js.push_str("  \"input_sparsity\": {\n");
    js.push_str(&format!(
        "    \"auto_cutoff\": {:.2},\n",
        gemm::sparse_auto_cutoff()
    ));
    js.push_str(&format!("    \"macs_total\": {},\n", sp_ops.macs_total));
    js.push_str(&format!("    \"macs_done\": {},\n", sp_ops.macs_done));
    js.push_str(&format!(
        "    \"macs_saved_output_pred\": {},\n",
        sp_ops.macs_total - sp_ops.macs_done
    ));
    js.push_str(&format!(
        "    \"macs_skipped_input_zero\": {},\n",
        sp_ops.macs_skipped_input_zero
    ));
    js.push_str(&format!(
        "    \"input_zero_frac_of_done\": {:.4},\n",
        sp_ops.input_zero_frac()
    ));
    js.push_str(&format!("    \"effectual_macs\": {},\n", sp_ops.effectual_macs()));
    js.push_str("    \"forward_ms\": {");
    for (i, (label, ms)) in sparse_ms.iter().enumerate() {
        if i > 0 {
            js.push_str(", ");
        }
        js.push_str(&format!("\"{label}\": {ms:.4}"));
    }
    js.push_str("}\n  },\n");
    // triple-sided accounting on the pruned model: output-prediction,
    // input-zero and weight-zero savings, per-mode wall-clock, and the
    // doubly-sparse intersection dot's throughput by weight density
    js.push_str("  \"weight_sparsity\": {\n");
    js.push_str(&format!(
        "    \"weight_cutoff\": {:.2},\n",
        crossover::weight_sparse_cutoff()
    ));
    js.push_str(&format!("    \"model_weight_zero_frac\": {w_zero_frac:.4},\n"));
    js.push_str(&format!("    \"macs_total\": {},\n", w_ops.macs_total));
    js.push_str(&format!("    \"macs_done\": {},\n", w_ops.macs_done));
    js.push_str(&format!(
        "    \"macs_saved_output_pred\": {},\n",
        w_ops.macs_total - w_ops.macs_done
    ));
    js.push_str(&format!(
        "    \"macs_skipped_input_zero\": {},\n",
        w_ops.macs_skipped_input_zero
    ));
    js.push_str(&format!(
        "    \"macs_skipped_weight_zero\": {},\n",
        w_ops.macs_skipped_weight_zero
    ));
    js.push_str(&format!(
        "    \"weight_zero_frac_of_done\": {:.4},\n",
        w_ops.weight_zero_frac()
    ));
    js.push_str(&format!("    \"effectual_macs\": {},\n", w_ops.effectual_macs()));
    js.push_str("    \"sparse_sparse_dot_gmacs\": {");
    for (i, (d, g)) in ss_gmacs.iter().enumerate() {
        if i > 0 {
            js.push_str(", ");
        }
        js.push_str(&format!("\"{d}\": {g:.4}"));
    }
    js.push_str("},\n");
    js.push_str("    \"forward_ms\": {");
    for (i, (label, ms)) in weight_ms.iter().enumerate() {
        if i > 0 {
            js.push_str(", ");
        }
        js.push_str(&format!("\"{label}\": {ms:.4}"));
    }
    js.push_str("}\n  },\n");
    // plan/workspace steady state: cached-plan vs per-call forward,
    // allocation count per request, workspace footprint per worker
    js.push_str("  \"plan\": {\n");
    js.push_str(&format!(
        "    \"allocs_per_request\": {allocs_per_request:.1},\n"
    ));
    js.push_str(&format!(
        "    \"workspace_bytes_per_worker\": {ws_bytes_per_worker},\n"
    ));
    js.push_str("    \"planned_ms\": {");
    for (i, (threads, planned, _)) in plan_ms.iter().enumerate() {
        if i > 0 {
            js.push_str(", ");
        }
        js.push_str(&format!("\"{threads}\": {planned:.4}"));
    }
    js.push_str("},\n");
    js.push_str("    \"legacy_percall_ms\": {");
    for (i, (threads, _, percall)) in plan_ms.iter().enumerate() {
        if i > 0 {
            js.push_str(", ");
        }
        js.push_str(&format!("\"{threads}\": {percall:.4}"));
    }
    js.push_str("}\n  },\n");
    js.push_str("  \"forward\": {\n");
    js.push_str(&format!("    \"model\": \"{model_label}\",\n"));
    js.push_str(&format!("    \"scalar_ref_ms\": {:.4},\n", t_scalar.min_ns / 1e6));
    js.push_str("    \"tiled_ms\": {");
    for (i, (threads, t)) in tiled.iter().enumerate() {
        if i > 0 {
            js.push_str(", ");
        }
        js.push_str(&format!("\"{threads}\": {:.4}", t.min_ns / 1e6));
    }
    js.push_str("},\n");
    js.push_str(&format!(
        "    \"speedup_1t_vs_scalar\": {:.4},\n",
        t_scalar.min_ns / t1
    ));
    let t4 = tiled
        .iter()
        .find(|(n, _)| *n == 4)
        .map(|(_, t)| t.min_ns)
        .unwrap_or(t1);
    js.push_str(&format!("    \"scaling_4t_vs_1t\": {:.4}\n", t1 / t4));
    js.push_str("  }\n}\n");
    match std::fs::write(&out_path, &js) {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => eprintln!("\nfailed to write {out_path}: {e}"),
    }

    strategy_overhead_bench(&arts, &xs, thr, &model_label);
}

/// The forward-pass workload: real cnn10 artifacts when available,
/// otherwise a synthetic cnn10-scale bundle (one throwaway data sample —
/// the bench input is generated separately below). The threshold keeps
/// each workload's historical BENCH series comparable: the default T
/// on real artifacts, 0.5 on the synthetic policy (whose correlations
/// are uniform in [0, 1)).
fn forward_workload() -> (mor::model::Artifacts, Vec<f32>, f32, String) {
    if let Some(zoo) = common::load_zoo() {
        if let Some(a) = zoo.into_iter().find(|a| a.meta.name == "cnn10") {
            let xs = a.data.test_sample(0).to_vec();
            let thr = PredictorConfig::default().threshold;
            return (a, xs, thr, "cnn10".to_string());
        }
    }
    let arts = synth::artifacts_for(synth::cnn10_like(21), 22, 1, 1);
    let (h, w, c) = arts.model.input_shape;
    let mut rng = Rng::new(23);
    let xs: Vec<f32> = (0..h * w * c).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
    (arts, xs, 0.5, "cnn10-synth".to_string())
}

/// §Strategies: predict-phase cost of each named strategy relative to
/// the dense `none` baseline, at 1/4/8 row-tile threads — the
/// machine-readable trajectory of "what does the skip decision cost vs
/// what does it save". Emits `BENCH_predictors.json` (override with
/// `MOR_BENCH_PREDICTORS_OUT`).
fn strategy_overhead_bench(
    arts: &mor::model::Artifacts,
    xs: &[f32],
    thr: f32,
    model_label: &str,
) {
    println!("\nper-strategy forward (tiled engine):");
    // prepare each strategy once (model clone + prepack + policy); the
    // thread sweep below derives cheap with_opts variants
    let sessions: Vec<(Strategy, Session)> = STRATEGIES
        .iter()
        .map(|&strategy| {
            let sess = Session::from_artifacts(
                arts,
                PredictorConfig { strategy, threshold: thr, ..Default::default() },
            );
            (strategy, sess)
        })
        .collect();
    let mut rows: Vec<String> = Vec::new();
    for threads in STRATEGY_THREADS {
        // `none` first: the denominator the others are measured against
        let mut none_ns = f64::NAN;
        for (strategy, base) in &sessions {
            let strategy = *strategy;
            let sess = base.with_opts(RunOpts {
                oracle: false,
                collect_trace: false,
                threads,
                engine: EngineSel::Tiled,
                ..Default::default()
            });
            let r = sess.run_sample(xs);
            let t = bench_with(
                &format!("{model_label} fwd, --predictor {:<7}, {threads} thread(s)", strategy.name()),
                1,
                0.3,
                &mut || {
                    black_box(sess.run_sample(black_box(xs)));
                },
            );
            t.report();
            if strategy == Strategy::None {
                none_ns = t.min_ns;
            }
            let overhead_pct = (t.min_ns / none_ns - 1.0) * 100.0;
            println!(
                "    macs saved {:.1}% | net vs none {overhead_pct:+.1}%",
                r.ops.macs_saved_frac() * 100.0
            );
            rows.push(format!(
                "    {{\"predictor\": \"{}\", \"threads\": {threads}, \
                 \"forward_ms\": {:.4}, \"overhead_vs_none_pct\": {overhead_pct:.2}, \
                 \"macs_saved_pct\": {:.2}, \"bin_ops_per_sample\": {}}}",
                strategy.name(),
                t.min_ns / 1e6,
                r.ops.macs_saved_frac() * 100.0,
                r.ops.bin_ops
            ));
        }
    }
    let out_path = std::env::var("MOR_BENCH_PREDICTORS_OUT")
        .unwrap_or_else(|_| "BENCH_predictors.json".to_string());
    let mut js = String::new();
    js.push_str("{\n");
    js.push_str("  \"bench\": \"perf_predictors\",\n");
    js.push_str(&common::provenance_json());
    js.push_str(&format!("  \"model\": \"{model_label}\",\n"));
    js.push_str(&format!(
        "  \"threads_available\": {},\n",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    ));
    js.push_str("  \"strategies\": [\n");
    js.push_str(&rows.join(",\n"));
    js.push_str("\n  ]\n}\n");
    match std::fs::write(&out_path, &js) {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => eprintln!("\nfailed to write {out_path}: {e}"),
    }
}
