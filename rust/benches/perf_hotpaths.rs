//! §Perf micro-benchmarks: the host hot paths tracked across the
//! optimization passes — dot kernels, the scalar GEMV vs tiled GEMM
//! engine, and the full MoR forward at 1/2/4/8 row-tile threads.
//!
//! Besides the human-readable report, emits `BENCH_hotpaths.json`
//! (override the path with `MOR_BENCH_OUT`) so the perf trajectory is
//! machine-diffable across PRs. Falls back to a synthetic cnn10-scale
//! model when `make artifacts` has not run, so the JSON is always
//! complete.
mod common;

use mor::engine::dot::dot_i8;
use mor::engine::gemm::{self, PrepackedFilters, NR};
use mor::model::synth;
use mor::predictor::{exec, EngineSel, MorPolicy, RunOpts};
use mor::util::bench::{bench_with, Timing};
use mor::util::bits::PackedVec;
use mor::util::rng::Rng;
use std::hint::black_box;

const FWD_THREADS: [usize; 4] = [1, 2, 4, 8];

fn main() {
    let mut rng = Rng::new(7);
    let k = 576usize; // largest K in the model zoo (3x3x64)
    let cout = 64usize;
    let rows = 64usize;
    let x: Vec<i8> = (0..k).map(|_| rng.int8()).collect();
    let w: Vec<i8> = (0..k).map(|_| rng.int8()).collect();

    // ---- single-dot kernels ---------------------------------------------
    let t_dot = bench_with("dot_i8 (K=576)", 10, 0.2, &mut || {
        black_box(dot_i8(black_box(&x), black_box(&w)));
    });
    t_dot.report();
    let dot_gmacs = k as f64 / t_dot.min_ns;
    println!("    ≈ {dot_gmacs:.2} GMAC/s single-thread (min)");

    let px = PackedVec::from_acts(&x);
    let pw = PackedVec::from_weights(&w);
    let t_bin = bench_with("packed binary dot (K=576)", 10, 0.2, &mut || {
        black_box(px.dot(black_box(&pw)));
    });
    t_bin.report();
    let bin_gops = k as f64 / t_bin.min_ns;

    // ---- scalar GEMV vs tiled GEMM on one dense layer -------------------
    let node = synth::dense_node(k, cout, 11);
    let pf = PrepackedFilters::new(&node);
    let patches: Vec<Vec<i8>> = (0..rows)
        .map(|_| (0..k).map(|_| rng.int8()).collect())
        .collect();
    let mut padded = vec![0i8; rows * pf.k_pad];
    for (r, p) in patches.iter().enumerate() {
        padded[r * pf.k_pad..r * pf.k_pad + k].copy_from_slice(p);
    }
    let work_macs = (rows * cout * k) as f64;

    let mut sink = 0i64;
    let t_gemv = bench_with("per-neuron GEMV (64 rows x 64 filters)", 3, 0.3, &mut || {
        let mut acc = 0i64;
        for p in &patches {
            for f in 0..cout {
                acc += dot_i8(p, node.filter(f)) as i64;
            }
        }
        sink ^= black_box(acc);
    });
    t_gemv.report();
    let gemv_gmacs = work_macs / t_gemv.min_ns;
    println!("    ≈ {gemv_gmacs:.2} GMAC/s");

    let t_gemm = bench_with("tiled GEMM micro-kernel (same work)", 3, 0.3, &mut || {
        let mut acc = 0i64;
        let mut blk = [0i32; NR];
        let mut f0 = 0;
        while f0 < cout {
            let nf = NR.min(cout - f0);
            for r in 0..rows {
                gemm::dot_block(&padded[r * pf.k_pad..(r + 1) * pf.k_pad], &pf, f0, nf, &mut blk);
                for &d in &blk[..nf] {
                    acc += d as i64;
                }
            }
            f0 += NR;
        }
        sink ^= black_box(acc);
    });
    t_gemm.report();
    let gemm_gmacs = work_macs / t_gemm.min_ns;
    println!(
        "    ≈ {gemm_gmacs:.2} GMAC/s ({:.2}x over per-neuron GEMV)",
        t_gemv.min_ns / t_gemm.min_ns
    );
    black_box(sink);

    // ---- full MoR forward: scalar reference vs tiled at 1/2/4/8 threads -
    let (model, pol, xs, model_label) = forward_workload();
    println!("\nfull MoR forward on {model_label}:");
    let scalar_opts = RunOpts {
        oracle: false,
        collect_trace: false,
        threads: 1,
        engine: EngineSel::ScalarRef,
    };
    let t_scalar = bench_with(
        &format!("{model_label} MoR fwd, per-neuron baseline"),
        1,
        0.5,
        &mut || {
            black_box(exec::run_sample(&model, Some(&pol), &xs, scalar_opts));
        },
    );
    t_scalar.report();

    let mut tiled: Vec<(usize, Timing)> = Vec::new();
    for threads in FWD_THREADS {
        let opts = RunOpts { threads, engine: EngineSel::Tiled, ..scalar_opts };
        let t = bench_with(
            &format!("{model_label} MoR fwd, tiled GEMM, {threads} thread(s)"),
            1,
            0.5,
            &mut || {
                black_box(exec::run_sample(&model, Some(&pol), &xs, opts));
            },
        );
        t.report();
        tiled.push((threads, t));
    }
    let t1 = tiled[0].1.min_ns;
    println!(
        "    single-thread speedup vs per-neuron: {:.2}x | 4-thread scaling: {:.2}x over 1-thread",
        t_scalar.min_ns / t1,
        t1 / tiled.iter().find(|(n, _)| *n == 4).map(|(_, t)| t.min_ns).unwrap_or(t1)
    );

    // ---- machine-readable trajectory ------------------------------------
    let out_path =
        std::env::var("MOR_BENCH_OUT").unwrap_or_else(|_| "BENCH_hotpaths.json".to_string());
    let mut js = String::new();
    js.push_str("{\n");
    js.push_str("  \"bench\": \"perf_hotpaths\",\n");
    js.push_str(&format!(
        "  \"threads_available\": {},\n",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    ));
    js.push_str(&format!("  \"dot_i8_gmacs\": {dot_gmacs:.4},\n"));
    js.push_str(&format!("  \"packed_bin_dot_gops\": {bin_gops:.4},\n"));
    js.push_str(&format!("  \"gemv_scalar_gmacs\": {gemv_gmacs:.4},\n"));
    js.push_str(&format!("  \"gemm_tiled_gmacs\": {gemm_gmacs:.4},\n"));
    js.push_str(&format!(
        "  \"gemm_vs_gemv_speedup\": {:.4},\n",
        t_gemv.min_ns / t_gemm.min_ns
    ));
    js.push_str("  \"forward\": {\n");
    js.push_str(&format!("    \"model\": \"{model_label}\",\n"));
    js.push_str(&format!("    \"scalar_ref_ms\": {:.4},\n", t_scalar.min_ns / 1e6));
    js.push_str("    \"tiled_ms\": {");
    for (i, (threads, t)) in tiled.iter().enumerate() {
        if i > 0 {
            js.push_str(", ");
        }
        js.push_str(&format!("\"{threads}\": {:.4}", t.min_ns / 1e6));
    }
    js.push_str("},\n");
    js.push_str(&format!(
        "    \"speedup_1t_vs_scalar\": {:.4},\n",
        t_scalar.min_ns / t1
    ));
    let t4 = tiled
        .iter()
        .find(|(n, _)| *n == 4)
        .map(|(_, t)| t.min_ns)
        .unwrap_or(t1);
    js.push_str(&format!("    \"scaling_4t_vs_1t\": {:.4}\n", t1 / t4));
    js.push_str("  }\n}\n");
    match std::fs::write(&out_path, &js) {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => eprintln!("\nfailed to write {out_path}: {e}"),
    }
}

/// The forward-pass workload: real cnn10 artifacts when available,
/// otherwise a synthetic cnn10-scale stack with a synthetic policy.
fn forward_workload() -> (mor::model::Model, MorPolicy, Vec<f32>, String) {
    if let Some(zoo) = common::load_zoo() {
        if let Some(a) = zoo.into_iter().find(|a| a.meta.name == "cnn10") {
            let pol = MorPolicy::new(&a.model, &a.predictor, Default::default());
            let xs = a.data.test_sample(0).to_vec();
            return (a.model, pol, xs, "cnn10".to_string());
        }
    }
    let model = synth::cnn10_like(21);
    let params = synth::predictor_for(&model, 22);
    let pol = MorPolicy::new(
        &model,
        &params,
        mor::config::PredictorConfig { threshold: 0.5, ..Default::default() },
    );
    let (h, w, c) = model.input_shape;
    let mut rng = Rng::new(23);
    let xs: Vec<f32> = (0..h * w * c).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
    (model, pol, xs, "cnn10-synth".to_string())
}
