//! Bench for paper Fig 1: % of MACs producing negative ReLU inputs, plus a
//! wall-clock micro-benchmark of the dense functional forward it uses.
mod common;
use mor::util::bench::{bench_with, Table};

fn main() {
    let Some(zoo) = common::load_zoo() else { return };
    let t: Table = mor::figures::fig01(&zoo, 32);
    t.print();
    t.write_csv(&common::out_dir(), "fig01_neg_relu").ok();

    // micro: dense forward throughput per model (feeds §Perf)
    println!("\n-- dense forward wall-clock --");
    for a in &zoo {
        let x = a.data.test_sample(0).to_vec();
        let timing = bench_with(&format!("{} dense fwd", a.meta.name), 1, 0.4, &mut || {
            std::hint::black_box(mor::predictor::exec::run_sample(
                &a.model,
                None,
                &x,
                mor::predictor::RunOpts {
                    oracle: false,
                    collect_trace: false,
                    ..Default::default()
                },
            ));
        });
        timing.report();
    }
}
