//! Bench for paper Fig 8: distribution of closest-neighbour angles.
mod common;
fn main() {
    let Some(zoo) = common::load_zoo() else { return };
    let t = mor::figures::fig08(&zoo);
    t.print();
    t.write_csv(&common::out_dir(), "fig08_angle_hist").ok();
}
