//! Bench for paper Fig 5: distribution of per-neuron Pearson correlation.
mod common;
fn main() {
    let Some(zoo) = common::load_zoo() else { return };
    let t = mor::figures::fig05(&zoo);
    t.print();
    t.write_csv(&common::out_dir(), "fig05_corr_hist").ok();
}
