//! Bench for paper Fig 3: % of MACs in each layer type.
mod common;
fn main() {
    let Some(zoo) = common::load_zoo() else { return };
    let t = mor::figures::fig03(&zoo);
    t.print();
    t.write_csv(&common::out_dir(), "fig03_mac_breakdown").ok();
}
