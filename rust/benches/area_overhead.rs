//! Bench for the paper's §6 area claim: MoR hardware adds ~5.3% area.
mod common;
use mor::config::Config;
fn main() {
    let t = mor::figures::area_table(&Config::default());
    t.print();
    t.write_csv(&common::out_dir(), "area_overhead").ok();
}
