//! Bench for paper Fig 9: hybrid Mixture-of-Rookies — accuracy loss vs %
//! computations avoided (must dominate the binary-only Fig 6 curves).
mod common;
use mor::predictor::strategies::Strategy;
fn main() {
    let Some(zoo) = common::load_zoo() else { return };
    let t = mor::figures::threshold_sweep(&zoo, 32, Strategy::Mor);
    t.print();
    t.write_csv(&common::out_dir(), "fig09_hybrid_sweep").ok();
}
