//! Ablation (DESIGN.md §6): each MoR component in isolation vs the hybrid,
//! at the default threshold — quantifies the paper's claim that the hybrid
//! "yields much better results than any of its two components in isolation".
mod common;
use mor::config::PredictorConfig;
use mor::predictor::{MorPolicy, MorRun, RunOpts};
use mor::util::bench::Table;

fn main() {
    let Some(zoo) = common::load_zoo() else { return };
    let samples = 32;
    let mut t = Table::new(
        "Ablation — components in isolation vs hybrid (default T)",
        &["model", "variant", "ops_saved_pct", "accuracy_loss_pct", "incorrect_zero_pct"],
    );
    for a in &zoo {
        let base = MorRun::evaluate(a, None, samples, RunOpts::default());
        for (label, use_bin, use_cl, gate) in [
            ("binary-only", true, false, 90.0f32),
            ("clusters-only", false, true, 90.0),
            ("hybrid", true, true, 90.0),
            ("hybrid+tight-angle-gate(80)", true, true, 80.0),
        ] {
            let pol = MorPolicy::new(
                &a.model,
                &a.predictor,
                PredictorConfig {
                    use_binary: use_bin,
                    use_clusters: use_cl,
                    max_cluster_angle_deg: gate,
                    ..Default::default()
                },
            );
            let s = MorRun::evaluate(a, Some(&pol), samples, RunOpts::default());
            t.row(&[
                a.meta.name.clone(),
                label.into(),
                format!("{:.2}", s.ops.macs_saved_frac() * 100.0),
                format!("{:.2}", (base.accuracy - s.accuracy) * 100.0),
                format!("{:.2}", s.pred.frac(s.pred.incorrect_zero) * 100.0),
            ]);
        }
    }
    t.print();
    t.write_csv(&common::out_dir(), "ablation_components").ok();
}
