//! Ablation (DESIGN.md §6): every named skip strategy on equal footing —
//! quantifies the paper's claim that the hybrid "yields much better
//! results than any of its two components in isolation", now bracketed
//! by the `oracle` upper bound and the `none` baseline.
mod common;

fn main() {
    let Some(zoo) = common::load_zoo() else { return };
    let t = mor::figures::strategy_ablation(&zoo, 32);
    t.print();
    t.write_csv(&common::out_dir(), "ablation_components").ok();
}
