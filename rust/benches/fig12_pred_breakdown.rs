//! Bench for paper Fig 12: prediction outcome breakdown at the default
//! operating point (paper: correct-zero 7-11%, incorrect-zero 0.4-3.6%).
mod common;
fn main() {
    let Some(zoo) = common::load_zoo() else { return };
    let (t, _) = mor::figures::fig12(&zoo, 32);
    t.print();
    t.write_csv(&common::out_dir(), "fig12_pred_breakdown").ok();
}
