//! Bench for the paper's §3.2.2 Monte Carlo validation of Eq. 3-6.
mod common;
fn main() {
    let t = mor::figures::montecarlo_table(200_000);
    t.print();
    t.write_csv(&common::out_dir(), "montecarlo_angles").ok();
}
