"""Offline-stage tests: regression fitting, angle clustering, serialization."""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

import compile.calibrate as C

FAST = settings(
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)


# ------------------------------------------------------------------ fit_lines


def test_fit_lines_exact_recovery():
    """Noise-free affine data must be recovered exactly (c = ±1)."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(200, 5)).astype(np.float32)
    m_true = np.array([2.0, -1.5, 0.5, 3.0, -0.25], np.float32)
    b_true = np.array([1.0, 0.0, -2.0, 0.5, 4.0], np.float32)
    y = x * m_true + b_true
    c, m, b, sd = C.fit_lines(x, y)
    np.testing.assert_allclose(sd, 0.0, atol=1e-3)  # noise-free data
    np.testing.assert_allclose(m, m_true, rtol=1e-4)
    np.testing.assert_allclose(b, b_true, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.abs(c), 1.0, atol=1e-5)


def test_fit_lines_constant_column_degenerate():
    """Zero-variance binary column → c=0, m=0 (predictor gets disabled)."""
    x = np.ones((50, 2), np.float32)
    x[:, 1] = np.linspace(0, 1, 50)
    y = np.random.default_rng(1).normal(size=(50, 2)).astype(np.float32)
    c, m, b, sd = C.fit_lines(x, y)
    assert c[0] == 0.0 and m[0] == 0.0
    np.testing.assert_allclose(b[0], y[:, 0].mean(), rtol=1e-5)


@given(
    r=st.integers(10, 300),
    n=st.integers(1, 20),
    seed=st.integers(0, 2**31 - 1),
)
@FAST
def test_fit_lines_pearson_in_range(r, n, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(r, n)).astype(np.float32)
    y = rng.normal(size=(r, n)).astype(np.float32)
    c, m, b, sd = C.fit_lines(x, y)
    assert np.all(np.abs(c) <= 1.0 + 1e-5)
    assert np.all(np.isfinite(m)) and np.all(np.isfinite(b))


def test_fit_lines_matches_numpy_polyfit():
    rng = np.random.default_rng(5)
    x = rng.normal(size=(100, 1)).astype(np.float32)
    y = (3 * x + rng.normal(scale=0.5, size=(100, 1))).astype(np.float32)
    c, m, b, sd = C.fit_lines(x, y)
    mm, bb = np.polyfit(x[:, 0].astype(np.float64), y[:, 0].astype(np.float64), 1)
    np.testing.assert_allclose(m[0], mm, rtol=1e-3)
    np.testing.assert_allclose(b[0], bb, rtol=1e-2, atol=1e-2)
    cc = np.corrcoef(x[:, 0], y[:, 0])[0, 1]
    np.testing.assert_allclose(c[0], cc, rtol=1e-4)


# ------------------------------------------------------------------ angles


def test_weight_angles_known_geometry():
    w = np.array(
        [[1, 0, -1, 1], [0, 1, 0, 1]], np.float32
    )  # columns: e1, e2, -e1, (1,1)/√2
    a = C.weight_angles_deg(w)
    np.testing.assert_allclose(a[0, 1], 90.0, atol=1e-4)
    np.testing.assert_allclose(a[0, 2], 180.0, atol=1e-4)
    np.testing.assert_allclose(a[0, 3], 45.0, atol=1e-4)
    # float32 cos ≈ 0.99999994 → arccos ≈ 0.02°; self-angle is only ~0
    np.testing.assert_allclose(np.diag(a), 0.0, atol=0.1)


def test_closest_neighbors_excludes_self():
    w = np.random.default_rng(2).normal(size=(10, 6)).astype(np.float32)
    idx, ang = C.closest_neighbors(C.weight_angles_deg(w))
    assert all(idx[i] != i for i in range(6))
    assert np.all(ang >= 0)


# ------------------------------------------------------------------ clusters


@given(n=st.integers(2, 60), k=st.integers(2, 30), seed=st.integers(0, 2**31 - 1))
@FAST
def test_cluster_partition_invariants(n, k, seed):
    """Paper's algorithm invariants: exact partition, proxy-first layout."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(k, n)).astype(np.float32)
    clusters, near = C.cluster_by_angle(w)
    seen = [x for cl in clusters for x in cl]
    assert sorted(seen) == list(range(n))  # partition: once, exactly
    for cl in clusters:
        assert len(cl) >= 1
        assert cl[0] not in cl[1:]  # proxy is not its own member
    assert near.shape == (n,)


def test_cluster_parallel_vectors_grouped():
    """Near-parallel columns must land in one cluster with high indegree."""
    rng = np.random.default_rng(3)
    base = rng.normal(size=(16,)).astype(np.float32)
    cols = [base + rng.normal(scale=0.01, size=16) for _ in range(5)]
    cols += [rng.normal(size=16) for _ in range(5)]
    w = np.stack(cols, axis=1).astype(np.float32)
    clusters, _ = C.cluster_by_angle(w)
    # closest-neighbour graphs don't guarantee ONE cluster for a parallel
    # bundle (the algorithm deliberately avoids chaining), but clusters
    # containing bundle vectors must contain ONLY bundle vectors, and at
    # least one real group must form.
    grouped = 0
    for cl in clusters:
        bundle = set(cl) & set(range(5))
        if bundle:
            assert bundle == set(cl), f"bundle mixed with scattered: {clusters}"
            grouped = max(grouped, len(cl))
    assert grouped >= 2, f"no grouping happened: {clusters}"


def test_cluster_max_angle_gate():
    """With a 0° gate no edges survive: every neuron is its own proxy."""
    rng = np.random.default_rng(4)
    w = rng.normal(size=(8, 12)).astype(np.float32)
    clusters, _ = C.cluster_by_angle(w, max_angle_deg=0.0)
    assert len(clusters) == 12
    assert all(len(cl) == 1 for cl in clusters)


# ------------------------------------------------------------------ montecarlo
# Verifies the paper's Eq. 3-6 (probability of sign agreement as a function
# of the angle), the analysis behind the clustering — the paper states they
# verified it with a Monte Carlo simulation; we reproduce that here (and in
# rust/src/cluster for higher dimensions).


@pytest.mark.parametrize("theta_deg", [10, 45, 90, 135, 170])
def test_montecarlo_sign_agreement_2d(theta_deg):
    rng = np.random.default_rng(theta_deg)
    th = np.radians(theta_deg)
    a = np.array([1.0, 0.0])
    b = np.array([np.cos(th), np.sin(th)])
    c = rng.normal(size=(200_000, 2))
    sa = (c @ a) > 0
    sb = (c @ b) > 0
    p_mismatch = float((sa != sb).mean())
    # Eq. 3+4: p(+-) + p(-+) = 2 * theta/360
    np.testing.assert_allclose(p_mismatch, 2 * theta_deg / 360.0, atol=5e-3)


def test_montecarlo_sign_agreement_high_dim():
    """The relation is exact in any dimension (rotation invariance)."""
    rng = np.random.default_rng(99)
    dim = 64
    a = rng.normal(size=dim)
    raw = rng.normal(size=dim)
    theta = 60.0
    # construct b at exactly 60° from a
    a_u = a / np.linalg.norm(a)
    perp = raw - (raw @ a_u) * a_u
    perp /= np.linalg.norm(perp)
    b = np.cos(np.radians(theta)) * a_u + np.sin(np.radians(theta)) * perp
    c = rng.normal(size=(200_000, dim))
    p_mismatch = float((((c @ a_u) > 0) != ((c @ b) > 0)).mean())
    np.testing.assert_allclose(p_mismatch, 2 * theta / 360.0, atol=5e-3)


# ------------------------------------------------------------------ json dict


def test_to_json_dict_roundtrip():
    import json

    rng = np.random.default_rng(11)
    w = rng.normal(size=(12, 8)).astype(np.float32)
    clusters, near = C.cluster_by_angle(w)
    lc = C.LayerCalibration(
        layer=3,
        c=rng.uniform(-1, 1, 8).astype(np.float32),
        m=rng.normal(size=8).astype(np.float32),
        b=rng.normal(size=8).astype(np.float32),
        s=np.abs(rng.normal(size=8)).astype(np.float32),
        clusters=clusters,
        closest_angle_deg=near,
    )
    cal = C.Calibration("toy", {3: lc})
    d = C.to_json_dict(cal, default_threshold=0.9)
    s = json.dumps(d)
    back = json.loads(s)
    assert back["model"] == "toy"
    assert back["default_threshold"] == 0.9
    lay = back["layers"][0]
    assert lay["layer"] == 3 and lay["neurons"] == 8
    assert sorted(x for cl in lay["clusters"] for x in cl) == list(range(8))
