"""Binary artifact format round-trips (the rust loaders parse these bytes)."""

from __future__ import annotations

import os
import struct
import tempfile

import jax.numpy as jnp
import numpy as np

import compile.artifacts_io as A
import compile.model as M
import compile.quantize as Q


def _quantized_toy():
    mdef = M.ZOO["tds"]()
    params, state = M.init_params(mdef, seed=7)
    x = jnp.asarray(
        np.random.default_rng(7).uniform(-1, 1, (4,) + mdef.input_shape).astype(np.float32)
    )
    return mdef, Q.quantize(mdef, params, state, x)


def test_weights_roundtrip():
    mdef, qm = _quantized_toy()
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "toy.w.bin")
        A.write_weights(path, qm)
        nodes = A.read_weights_header(path)
    assert len(nodes) == len(mdef.nodes)
    for i, nd in enumerate(mdef.nodes):
        parsed = nodes[i]
        if isinstance(nd, M.Conv):
            assert parsed["kind"] == A.KIND_CONV
            np.testing.assert_array_equal(parsed["w"], qm.layers[i].w_int8)
            assert parsed["flags"] & 1 == (1 if nd.relu else 0)
            assert abs(parsed["sw"] - qm.layers[i].sw) < 1e-6
        elif isinstance(nd, M.FC):
            assert parsed["kind"] == A.KIND_FC
            np.testing.assert_array_equal(parsed["w"], qm.layers[i].w_int8)
        elif isinstance(nd, M.GAP):
            assert parsed["kind"] == A.KIND_GAP
        assert parsed["consumes"] == M.input_of(mdef, i)


def test_weights_bn_payload():
    mdef = M.ZOO["cnn10"]()
    params, state = M.init_params(mdef, seed=3)
    x = jnp.asarray(
        np.random.default_rng(3).uniform(-1, 1, (2,) + mdef.input_shape).astype(np.float32)
    )
    qm = Q.quantize(mdef, params, state, x)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "toy.w.bin")
        A.write_weights(path, qm)
        nodes = A.read_weights_header(path)
    for i, nd in enumerate(mdef.nodes):
        if isinstance(nd, M.Conv) and nd.bn:
            np.testing.assert_allclose(nodes[i]["bn_scale"], qm.layers[i].bn_scale, rtol=1e-6)
            np.testing.assert_allclose(nodes[i]["bn_shift"], qm.layers[i].bn_shift, rtol=1e-6)


def test_data_roundtrip():
    rng = np.random.default_rng(0)
    tx = rng.uniform(-1, 1, (6, 4, 1, 3)).astype(np.float32)
    ty = rng.integers(0, 10, 6).astype(np.uint16)
    cx = rng.uniform(-1, 1, (3, 4, 1, 3)).astype(np.float32)
    cy = rng.integers(0, 10, 3).astype(np.uint16)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "toy.data.bin")
        A.write_data(path, tx, ty, cx, cy)
        buf = open(path, "rb").read()
    assert buf[:4] == b"MORD"
    ver, n_test, n_calib, h, w, c = struct.unpack_from("<IIIIII", buf, 4)
    assert (ver, n_test, n_calib, h, w, c) == (1, 6, 3, 4, 1, 3)
    off = 28
    tx2 = np.frombuffer(buf, "<f4", 6 * 4 * 1 * 3, off).reshape(6, 4, 1, 3)
    np.testing.assert_array_equal(tx2, tx)
    off += tx2.nbytes
    ty2 = np.frombuffer(buf, "<u2", 6, off)
    np.testing.assert_array_equal(ty2, ty)
    off += ty2.nbytes
    cx2 = np.frombuffer(buf, "<f4", 3 * 4 * 1 * 3, off).reshape(3, 4, 1, 3)
    np.testing.assert_array_equal(cx2, cx)
    off += cx2.nbytes
    cy2 = np.frombuffer(buf, "<u2", 3, off)
    np.testing.assert_array_equal(cy2, cy)
    assert off + cy2.nbytes == len(buf)


def test_file_sizes_are_deterministic():
    _, qm = _quantized_toy()
    with tempfile.TemporaryDirectory() as d:
        p1, p2 = os.path.join(d, "a.bin"), os.path.join(d, "b.bin")
        A.write_weights(p1, qm)
        A.write_weights(p2, qm)
        assert open(p1, "rb").read() == open(p2, "rb").read()
