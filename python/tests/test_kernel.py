"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (including non-tile-aligned ones) and value
distributions; integer kernels must agree *exactly* with the oracle,
float outputs within float32 tolerance.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from compile.kernels import binary_dot as bd
from compile.kernels import conv2d as cv
from compile.kernels import int8_matmul as mm
from compile.kernels import mor_dense as md
from compile.kernels import ref

FAST = settings(
    max_examples=20,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)


def _int8(rng, *shape):
    return jnp.asarray(rng.integers(-128, 128, shape, dtype=np.int8))


# ---------------------------------------------------------------- int8_matmul


@given(
    m=st.integers(1, 70),
    k=st.integers(1, 200),
    n=st.integers(1, 70),
    seed=st.integers(0, 2**31 - 1),
)
@FAST
def test_int8_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = _int8(rng, m, k), _int8(rng, k, n)
    got = mm.int8_matmul(x, w)
    want = ref.int8_matmul(x, w)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 32, 64), (32, 64, 64)])
def test_int8_matmul_tile_shapes(bm, bn, bk):
    rng = np.random.default_rng(0)
    x, w = _int8(rng, 33, 130), _int8(rng, 130, 65)
    got = mm.int8_matmul(x, w, bm=bm, bn=bn, bk=bk)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.int8_matmul(x, w)))


def test_int8_matmul_extremes():
    """Saturated inputs: |dot| can reach K*127*127 — must not overflow int32
    at the sizes the model zoo uses (K <= 1440)."""
    k = 1440
    x = jnp.full((2, k), -127, jnp.int8)
    w = jnp.full((k, 3), 127, jnp.int8)
    got = mm.int8_matmul(x, w)
    assert int(got[0, 0]) == -127 * 127 * k


def test_vmem_budget():
    """Default tiles stay under a 128 KiB VMEM-class working set."""
    assert mm.vmem_bytes() < 128 * 1024


# ---------------------------------------------------------------- binary_dot


@given(
    m=st.integers(1, 40),
    k=st.integers(1, 150),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
@FAST
def test_binary_dot_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = _int8(rng, m, k), _int8(rng, k, n)
    got = bd.binary_dot(x, w)
    want = ref.binary_dot(x, w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_binary_dot_zero_conventions():
    """act(0) = -1 (inactive), sign(0) = +1: the asymmetry that keeps
    post-ReLU layers informative (see ref.py docstring)."""
    x = jnp.asarray([[0, 5, 0]], jnp.int8)
    w = jnp.asarray([[0], [0], [-3]], jnp.int8)
    # acts: -1,+1,-1 ; weights: +1,+1,-1 → -1 + 1 + 1 = 1
    assert int(bd.binary_dot(x, w)[0, 0]) == 1
    assert int(ref.binary_dot(x, w)[0, 0]) == 1


def test_binary_dot_range():
    rng = np.random.default_rng(3)
    x, w = _int8(rng, 9, 77), _int8(rng, 77, 11)
    got = np.asarray(bd.binary_dot(x, w))
    assert got.max() <= 77 and got.min() >= -77
    # parity: p_bin has the same parity as K
    assert ((got - 77) % 2 == 0).all()


# ----------------------------------------------------------------- mor_dense


@given(
    m=st.integers(1, 24),
    k=st.integers(2, 100),
    n=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
    use_bn=st.booleans(),
    use_res=st.booleans(),
)
@FAST
def test_mor_dense_matches_ref(m, k, n, seed, use_bn, use_res):
    rng = np.random.default_rng(seed)
    x, w = _int8(rng, m, k), _int8(rng, k, n)
    slope = jnp.asarray(rng.normal(size=n).astype(np.float32))
    inter = jnp.asarray(rng.normal(size=n).astype(np.float32))
    if use_bn:
        sc = jnp.asarray((rng.uniform(0.1, 2, n)).astype(np.float32))
        sh = jnp.asarray(rng.normal(size=n).astype(np.float32))
    else:
        sc, sh = jnp.ones((n,), jnp.float32), jnp.zeros((n,), jnp.float32)
    res = (
        jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
        if use_res
        else jnp.zeros((m, n), jnp.float32)
    )
    en = jnp.asarray(rng.integers(0, 2, n).astype(bool))
    dq = float(rng.uniform(0.001, 0.1))
    y1, s1 = md.mor_dense(x, w, slope, inter, sc, sh, res, en, dq)
    y2, s2 = ref.mor_dense(x, w, slope, inter, sc, sh, res, en, dq)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-5)


def test_mor_dense_skip_forces_zero():
    rng = np.random.default_rng(1)
    x, w = _int8(rng, 16, 64), _int8(rng, 64, 32)
    n = 32
    slope = jnp.zeros((n,), jnp.float32)
    inter = jnp.full((n,), -1.0, jnp.float32)  # estimate always negative
    en = jnp.ones((n,), bool)
    y, s = md.mor_dense(
        x, w, slope, inter,
        jnp.ones((n,)), jnp.zeros((n,)), jnp.zeros((16, n)), en, 0.01,
    )
    assert bool(jnp.all(s)) and float(jnp.abs(y).max()) == 0.0


def test_mor_dense_disabled_never_skips():
    rng = np.random.default_rng(2)
    x, w = _int8(rng, 8, 32), _int8(rng, 32, 16)
    nn = 16
    slope = jnp.zeros((nn,), jnp.float32)
    inter = jnp.full((nn,), -1.0, jnp.float32)
    en = jnp.zeros((nn,), bool)
    _, s = md.mor_dense(
        x, w, slope, inter,
        jnp.ones((nn,)), jnp.zeros((nn,)), jnp.zeros((8, nn)), en, 0.01,
    )
    assert not bool(jnp.any(s))


# -------------------------------------------------------------------- conv2d


@given(
    h=st.integers(4, 14),
    w=st.integers(1, 14),
    c=st.integers(1, 8),
    f=st.integers(1, 12),
    kh=st.integers(1, 3),
    kw=st.integers(1, 3),
    stride=st.integers(1, 2),
    seed=st.integers(0, 2**31 - 1),
)
@FAST
def test_conv2d_matches_ref(h, w, c, f, kh, kw, stride, seed):
    if kh > h or kw > w:
        return
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-128, 128, (h, w, c), dtype=np.int8))
    wt = jnp.asarray(rng.integers(-128, 128, (kh, kw, c, f), dtype=np.int8))
    got = cv.conv2d_int8(x, wt, stride=stride)
    want = ref.conv2d_int8(x, wt, stride=stride)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_conv2d_matches_lax():
    """Cross-check the oracle itself against lax.conv (independent impl)."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.integers(-128, 128, (10, 10, 3), dtype=np.int8))
    wt = jnp.asarray(rng.integers(-128, 128, (3, 3, 3, 5), dtype=np.int8))
    want = jax.lax.conv_general_dilated(
        x[None], wt, (1, 1), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.int32,
    )[0]
    got = ref.conv2d_int8(x, wt, stride=1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
