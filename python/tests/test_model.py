"""L2 model-zoo tests: graph topology, shapes, float vs integer forwards."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import compile.model as M
import compile.quantize as Q


@pytest.fixture(scope="module", params=list(M.ZOO))
def model_name(request):
    return request.param


def small_batch(mdef, n=4, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.uniform(-1, 1, (n,) + mdef.input_shape).astype(np.float32)
    )


def test_forward_shapes(model_name):
    mdef = M.ZOO[model_name]()
    params, state = M.init_params(mdef)
    x = small_batch(mdef)
    logits, _ = M.forward(mdef, params, state, x, train=False)
    assert logits.shape == (4, mdef.num_classes)
    assert bool(jnp.isfinite(logits).all())


def test_node_shapes_consistent_with_forward(model_name):
    """Static shape inference must match the actual traced shapes."""
    mdef = M.ZOO[model_name]()
    params, state = M.init_params(mdef)
    shapes = M.node_shapes(mdef)
    x = small_batch(mdef, n=2)
    outs = Q._float_node_outputs(mdef, params, state, x)
    for i, (o, s) in enumerate(zip(outs, shapes)):
        assert o.shape[1:] == s, f"node {i}: {o.shape[1:]} != {s}"


def test_mac_counts_positive(model_name):
    mdef = M.ZOO[model_name]()
    macs = M.mac_counts(mdef)
    assert all(m >= 0 for m in macs)
    compute = [
        i for i, nd in enumerate(mdef.nodes) if isinstance(nd, (M.Conv, M.FC))
    ]
    assert all(macs[i] > 0 for i in compute)
    assert sum(macs) > 1_000_000  # each model is a real workload


def test_relu_layers_are_compute_nodes(model_name):
    mdef = M.ZOO[model_name]()
    for i in mdef.relu_layers():
        assert isinstance(mdef.nodes[i], (M.Conv, M.FC))


def test_projection_topology_resnet():
    """Projection shortcuts consume the same input as the conv they bypass."""
    mdef = M.ZOO["resnet18m"]()
    projections = [i for i in range(len(mdef.nodes)) if M.is_projection(mdef, i)]
    assert projections, "resnet18m must contain projection shortcuts"
    for p in projections:
        # the node after the projection consumes the projection's own input
        assert M.consumes(mdef, p + 1) == M.input_of(mdef, p)
        # some later node adds the projection output as residual
        assert any(
            getattr(nd, "res_from", None) == p for nd in mdef.nodes[p + 1 :]
        )


def test_quant_forward_close_to_float(model_name):
    """int8 logits must usually preserve the float argmax on random init."""
    mdef = M.ZOO[model_name]()
    params, state = M.init_params(mdef)
    x = small_batch(mdef, n=8)
    fl, _ = M.forward(mdef, params, state, x, train=False)
    qm = Q.quantize(mdef, params, state, x)
    ql, _ = Q.quant_forward(qm, x)
    # top-1 agreement on most samples (quantization noise tolerated)
    agree = float((jnp.argmax(fl, 1) == jnp.argmax(ql, 1)).mean())
    assert agree >= 0.5, f"int8 path diverges from float: agree={agree}"


def test_quant_forward_taps_shapes(model_name):
    mdef = M.ZOO[model_name]()
    params, state = M.init_params(mdef)
    x = small_batch(mdef, n=2)
    qm = Q.quantize(mdef, params, state, x)
    _, taps = Q.quant_forward(qm, x, collect=True)
    assert set(taps) == set(mdef.relu_layers())
    shapes = M.node_shapes(mdef)
    for i, (pbin, pbase) in taps.items():
        oh, ow, cout = shapes[i]
        assert pbin.shape == (2 * oh * ow, cout)
        assert pbase.shape == pbin.shape
        # binary counts are integers with |p_bin| <= K
        nd = mdef.nodes[i]
        assert float(jnp.max(jnp.abs(pbin))) <= _k_of(mdef, i) + 1e-6


def _k_of(mdef, i):
    nd = mdef.nodes[i]
    shapes = M.node_shapes(mdef)
    src = M.input_of(mdef, i)
    cin = (mdef.input_shape if src == -1 else shapes[src])[2]
    if isinstance(nd, M.Conv):
        return nd.kh * nd.kw * cin
    return cin


def test_deploy_forward_matches_quant_forward():
    """The Pallas deploy path and the jnp fast path agree (tds, small)."""
    mdef = M.ZOO["tds"]()
    params, state = M.init_params(mdef)
    x = small_batch(mdef, n=2)
    qm = Q.quantize(mdef, params, state, x)
    ql, _ = Q.quant_forward(qm, x)
    for s in range(2):
        dep = Q.deploy_forward(qm, x[s])
        np.testing.assert_allclose(
            np.asarray(dep), np.asarray(ql[s]), rtol=1e-4, atol=1e-4
        )


def test_deploy_forward_matches_quant_forward_conv_bn():
    """Same check for a BN+stride+pool model (cnn10 head is enough)."""
    mdef = M.ZOO["cnn10"]()
    params, state = M.init_params(mdef)
    x = small_batch(mdef, n=1)
    qm = Q.quantize(mdef, params, state, x)
    ql, _ = Q.quant_forward(qm, x)
    dep = Q.deploy_forward(qm, x[0])
    np.testing.assert_allclose(np.asarray(dep), np.asarray(ql[0]), rtol=1e-4, atol=1e-4)
