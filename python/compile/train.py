"""Build-time training of the model zoo on the synthetic datasets.

The paper evaluates *pre-trained* networks; the predictor never touches
training. We therefore only need models trained well enough that their
weight/activation statistics are those of a converged classifier (mixed
positive/negative dot products, class-selective filters). A few hundred
Adam steps on the synthetic tasks reach >90% test accuracy for every model.

No optax in the offline vendor set — Adam is implemented inline.
"""

from __future__ import annotations

import time
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets, model as M


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, opt, lr=2e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = opt["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, opt["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, opt["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}


def train_model(
    mdef: M.ModelDef,
    steps: int = 400,
    batch: int = 64,
    lr: float = 2e-3,
    seed: int = 0,
    log_every: int = 100,
) -> Tuple[list, list, dict]:
    """Train; returns (params, bn_state, info). info has loss curve + accuracy."""
    if mdef.input_shape[1] == 1:  # sequence model
        xtr, ytr, xte, yte = datasets.sequence_dataset()
        xtr = xtr[:, :, None, :]  # (N,T,1,F)
        xte = xte[:, :, None, :]
    else:
        xtr, ytr, xte, yte = datasets.image_dataset()

    params, state = M.init_params(mdef, seed)
    opt = adam_init(params)

    def loss_fn(params, state, xb, yb):
        logits, new_state = M.forward(mdef, params, state, xb, train=True)
        return cross_entropy(logits, yb), new_state

    @jax.jit
    def step_fn(params, state, opt, xb, yb):
        (loss, new_state), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, state, xb, yb
        )
        params, opt = adam_update(params, grads, opt, lr)
        return params, new_state, opt, loss

    rng = np.random.default_rng(seed)
    n = xtr.shape[0]
    losses = []
    t0 = time.time()
    for s in range(steps):
        idx = rng.integers(0, n, size=batch)
        params, state, opt, loss = step_fn(params, state, opt, xtr[idx], ytr[idx])
        losses.append(float(loss))
        if log_every and (s + 1) % log_every == 0:
            print(f"  [{mdef.name}] step {s+1}/{steps} loss={float(loss):.4f}")

    acc = float(accuracy(mdef, params, state, xte, yte))
    info = {
        "losses": losses,
        "test_accuracy": acc,
        "train_seconds": time.time() - t0,
        "steps": steps,
    }
    print(f"  [{mdef.name}] test top-1 = {acc*100:.1f}%  ({info['train_seconds']:.0f}s)")
    return params, state, info


def accuracy(mdef, params, state, x, y, batch: int = 256) -> float:
    hits = 0
    for i in range(0, x.shape[0], batch):
        logits, _ = M.forward(mdef, params, state, x[i : i + batch], train=False)
        hits += int((jnp.argmax(logits, axis=1) == y[i : i + batch]).sum())
    return hits / x.shape[0]


def test_split(mdef: M.ModelDef):
    """The (x_test, y_test) split a model is evaluated on (4-D inputs)."""
    if mdef.input_shape[1] == 1:
        _, _, xte, yte = datasets.sequence_dataset()
        return xte[:, :, None, :], yte
    _, _, xte, yte = datasets.image_dataset()
    return xte, yte


def calib_split(mdef: M.ModelDef, n: int = 128):
    """Calibration subset drawn from *training* data (as the paper does)."""
    if mdef.input_shape[1] == 1:
        xtr, ytr, _, _ = datasets.sequence_dataset()
        return xtr[:n, :, None, :], ytr[:n]
    xtr, ytr, _, _ = datasets.image_dataset()
    return xtr[:n], ytr[:n]
