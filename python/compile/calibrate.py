"""Offline stage of Mixture-of-Rookies (Section 3.2): self-correlation
profiling and angle-based clustering.

Outputs, per ReLU compute layer:

* per-neuron Pearson correlation ``c`` between the binary dot product and
  the base-precision dot product over a calibration subset;
* per-neuron fitted line ``(m, b)`` mapping binary counts to dequantized
  base dot products (least squares);
* clusters: the paper's algorithm — directed graph of each neuron to its
  closest-by-angle peer, proxies chosen by descending indegree;
* the closest-neighbour angle distribution (Fig 8).

All of this is exported in ``<model>.predictor.json`` and re-verified by the
rust implementation (rust/src/cluster) — the clustering is intentionally
implemented twice and property-tested for agreement of invariants.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

import numpy as np

from . import model as M
from . import quantize as Q


@dataclass
class LayerCalibration:
    layer: int
    c: np.ndarray          # (N,) Pearson correlation
    m: np.ndarray          # (N,) slope (dequant units per binary count)
    b: np.ndarray          # (N,) intercept
    s: np.ndarray          # (N,) regression residual std (margin unit)
    clusters: List[List[int]]  # each: [proxy, member, member, ...]
    closest_angle_deg: np.ndarray  # (N,) angle to closest neuron


@dataclass
class Calibration:
    model: str
    layers: Dict[int, LayerCalibration] = field(default_factory=dict)


# --------------------------------------------------------------------------
# Self-correlation: Pearson + least squares per neuron
# --------------------------------------------------------------------------


def fit_lines(pbin: np.ndarray, pbase: np.ndarray):
    """Column-wise linear regression pbase ~ m*pbin + b, Pearson c, and the
    regression's residual std s (the skip-confidence margin unit used by
    the online predictor: skip only when the estimate is k*s below zero).

    pbin/pbase: (R, N). Degenerate columns (zero variance) get c=0, m=0,
    b=mean(pbase): a constant predictor, which the threshold then disables.
    """
    r = pbin.shape[0]
    mx = pbin.mean(axis=0)
    my = pbase.mean(axis=0)
    dx = pbin - mx
    dy = pbase - my
    sxx = (dx * dx).sum(axis=0)
    syy = (dy * dy).sum(axis=0)
    sxy = (dx * dy).sum(axis=0)
    with np.errstate(divide="ignore", invalid="ignore"):
        m = np.where(sxx > 0, sxy / np.maximum(sxx, 1e-12), 0.0)
        denom = np.sqrt(np.maximum(sxx * syy, 1e-24))
        c = np.where((sxx > 0) & (syy > 0), sxy / denom, 0.0)
    b = my - m * mx
    resid = pbase - (pbin * m[None, :] + b[None, :])
    s_ = np.sqrt((resid * resid).sum(axis=0) / max(r - 2, 1))
    return (
        c.astype(np.float32),
        m.astype(np.float32),
        b.astype(np.float32),
        s_.astype(np.float32),
    )


# --------------------------------------------------------------------------
# Spatial correlation: angle-based clustering (Section 3.2.2)
# --------------------------------------------------------------------------


def weight_angles_deg(wmat: np.ndarray) -> np.ndarray:
    """Pairwise angles (degrees) between weight columns. wmat: (K, N)."""
    norms = np.linalg.norm(wmat, axis=0)
    norms = np.where(norms == 0, 1.0, norms)
    u = wmat / norms
    cos = np.clip(u.T @ u, -1.0, 1.0)
    return np.degrees(np.arccos(cos))


def closest_neighbors(angles: np.ndarray):
    """(closest index, closest angle) per neuron, self excluded."""
    a = angles.copy()
    np.fill_diagonal(a, np.inf)
    idx = a.argmin(axis=1)
    return idx, a[np.arange(a.shape[0]), idx]


def cluster_by_angle(
    wmat: np.ndarray, max_angle_deg: float = 90.0
) -> (List[List[int]], np.ndarray):
    """The paper's clustering algorithm.

    1. directed graph: each neuron -> its closest neuron (edge dropped if the
       angle exceeds ``max_angle_deg``; at >= 90° the false-positive
       probability of Eq. 4 reaches its maximum, so such edges carry no
       signal — with the paper's default this only removes degenerate edges);
    2. sort nodes by descending indegree;
    3. repeatedly: take the live node with highest indegree as *proxy*,
       remove it and all live nodes pointing at it (its cluster members).

    Returns (clusters, closest_angles). Every neuron appears in exactly one
    cluster; singleton clusters are plain unpredicted neurons.
    """
    n = wmat.shape[1]
    angles = weight_angles_deg(wmat)
    nearest, near_angle = closest_neighbors(angles)
    edge_to = np.where(near_angle <= max_angle_deg, nearest, -1)

    indegree = np.zeros(n, dtype=np.int64)
    for src in range(n):
        if edge_to[src] >= 0:
            indegree[edge_to[src]] += 1

    order = sorted(range(n), key=lambda i: (-indegree[i], i))
    alive = np.ones(n, dtype=bool)
    clusters: List[List[int]] = []
    # incoming adjacency
    incoming: List[List[int]] = [[] for _ in range(n)]
    for src in range(n):
        if edge_to[src] >= 0:
            incoming[edge_to[src]].append(src)

    for node in order:
        if not alive[node]:
            continue
        members = [m for m in incoming[node] if alive[m] and m != node]
        clusters.append([node] + members)
        alive[node] = False
        for m in members:
            alive[m] = False
    assert sum(len(c) for c in clusters) == n
    return clusters, near_angle.astype(np.float32)


# --------------------------------------------------------------------------
# Full offline pass
# --------------------------------------------------------------------------


def calibrate(
    qm: Q.QuantModel,
    calib_x,
    batch: int = 32,
    max_rows_per_layer: int = 200_000,
    max_angle_deg: float = 90.0,
    seed: int = 0,
) -> Calibration:
    """Run the calibration subset through the integer forward, fit the
    per-neuron lines, and cluster each ReLU layer's weight vectors."""
    import jax.numpy as jnp

    mdef = qm.mdef
    relu_layers = mdef.relu_layers()
    acc: Dict[int, List[np.ndarray]] = {i: [] for i in relu_layers}

    n = calib_x.shape[0]
    for s in range(0, n, batch):
        _, taps = Q.quant_forward(qm, jnp.asarray(calib_x[s : s + batch]), collect=True)
        for i in relu_layers:
            pbin, pbase = taps[i]
            acc[i].append((np.asarray(pbin), np.asarray(pbase)))

    cal = Calibration(mdef.name)
    rng = np.random.default_rng(seed)
    for i in relu_layers:
        pbin = np.concatenate([p for p, _ in acc[i]], axis=0)
        pbase = np.concatenate([q for _, q in acc[i]], axis=0)
        if pbin.shape[0] > max_rows_per_layer:
            sel = rng.choice(pbin.shape[0], max_rows_per_layer, replace=False)
            pbin, pbase = pbin[sel], pbase[sel]
        c, m, b, s_ = fit_lines(pbin, pbase)

        nd = mdef.nodes[i]
        w = qm.layers[i].w_int8.astype(np.float32)
        wmat = w.reshape(-1, nd.cout)  # (K, N) — filters flattened as columns
        clusters, near_angle = cluster_by_angle(wmat, max_angle_deg)
        cal.layers[i] = LayerCalibration(i, c, m, b, s_, clusters, near_angle)
    return cal


def to_json_dict(cal: Calibration, default_threshold: float = 0.85) -> dict:
    """Serializable form consumed by rust/src/model/predictor loader."""
    return {
        "model": cal.model,
        "default_threshold": default_threshold,
        "layers": [
            {
                "layer": lc.layer,
                "neurons": int(lc.c.shape[0]),
                "c": [round(float(v), 6) for v in lc.c],
                "m": [round(float(v), 8) for v in lc.m],
                "b": [round(float(v), 6) for v in lc.b],
                "s": [round(float(v), 6) for v in lc.s],
                "clusters": [[int(x) for x in cl] for cl in lc.clusters],
                "closest_angle_deg": [round(float(v), 3) for v in lc.closest_angle_deg],
            }
            for lc in cal.layers.values()
        ],
    }
