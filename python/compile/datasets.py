"""Synthetic datasets for build-time training of the MoR model zoo.

The paper trains TDS on Librispeech and the CNNs on ImageNet/CIFAR-10.
Those corpora (and the training budget) are out of scope for a build-time
artifact pass, so we substitute *structurally equivalent* synthetic tasks
(see DESIGN.md §3): every MoR mechanism we reproduce depends on trained
weight statistics and block structure, not on dataset scale.

Two generators:

* ``image_dataset``  — 10-class 16x16x3 images. Each class is a smooth
  random template; samples are the template under random shift, per-pixel
  noise and global gain. Learnable to >90% top-1 by the small CNNs, which
  leaves the trained filters with the mixed positive/negative dot-product
  statistics the predictor exploits.
* ``sequence_dataset`` — 10-class "utterances": T x F frame matrices built
  from class-specific frequency envelopes, mimicking the mel-frame inputs of
  the TDS speech network.

Everything is deterministic given ``seed``.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

NUM_CLASSES = 10
IMAGE_HW = 16
IMAGE_C = 3
SEQ_T = 32
SEQ_F = 40


def _smooth2d(rng: np.random.Generator, hw: int, c: int) -> np.ndarray:
    """Low-frequency random template: random field blurred by box filters."""
    x = rng.normal(size=(hw + 8, hw + 8, c))
    k = np.ones((5, 5)) / 25.0
    out = np.empty((hw, hw, c))
    for ch in range(c):
        pad = x[:, :, ch]
        # two box blurs ~= gaussian
        for _ in range(2):
            acc = np.zeros_like(pad)
            for dy in range(-2, 3):
                for dx in range(-2, 3):
                    acc += np.roll(np.roll(pad, dy, 0), dx, 1) * k[dy + 2, dx + 2]
            pad = acc
        out[:, :, ch] = pad[4 : 4 + hw, 4 : 4 + hw]
    out /= np.abs(out).max() + 1e-8
    return out


def image_dataset(n_train: int = 2048, n_test: int = 512, seed: int = 0):
    """Return (x_train, y_train, x_test, y_test) float32 in [-1, 1].

    Class templates share a common component (classes are *confusable*) and
    samples carry heavy noise + jitter: the models top out around 85-95%
    test accuracy, which leaves a measurable margin for the predictor's
    accuracy-loss curves (Fig 6 / Fig 9) instead of a saturated 100%.
    """
    rng = np.random.default_rng(seed)
    shared = _smooth2d(rng, IMAGE_HW, IMAGE_C)
    uniques = [_smooth2d(rng, IMAGE_HW, IMAGE_C) for _ in range(NUM_CLASSES)]
    templates = np.stack([0.65 * shared + 0.35 * u for u in uniques])
    n = n_train + n_test
    labels = rng.integers(0, NUM_CLASSES, size=n)
    xs = np.empty((n, IMAGE_HW, IMAGE_HW, IMAGE_C), np.float32)
    for i, lab in enumerate(labels):
        t = templates[lab]
        dy, dx = rng.integers(-3, 4, size=2)
        img = np.roll(np.roll(t, dy, 0), dx, 1)
        gain = rng.uniform(0.5, 1.5)
        noise = rng.normal(scale=0.55, size=img.shape)
        xs[i] = np.clip(img * gain + noise, -1.0, 1.0)
    y = labels.astype(np.int32)
    return (
        jnp.asarray(xs[:n_train]),
        jnp.asarray(y[:n_train]),
        jnp.asarray(xs[n_train:]),
        jnp.asarray(y[n_train:]),
    )


def sequence_dataset(n_train: int = 2048, n_test: int = 512, seed: int = 1):
    """Speech-like sequences: (N, T, F) float32 in [-1, 1], one label each."""
    rng = np.random.default_rng(seed)
    # class-specific spectral envelope + temporal modulation; envelopes share
    # a common component so classes are confusable (see image_dataset note)
    shared_env = rng.normal(size=SEQ_F)
    envelopes = 0.68 * shared_env + 0.32 * rng.normal(size=(NUM_CLASSES, SEQ_F))
    envelopes /= np.abs(envelopes).max(axis=1, keepdims=True)
    rates = rng.uniform(1.0, 1.8, size=NUM_CLASSES)
    phases_c = rng.uniform(0, 2 * np.pi, size=NUM_CLASSES)
    n = n_train + n_test
    labels = rng.integers(0, NUM_CLASSES, size=n)
    t = np.arange(SEQ_T)[:, None]  # (T, 1)
    xs = np.empty((n, SEQ_T, SEQ_F), np.float32)
    for i, lab in enumerate(labels):
        mod = np.sin(2 * np.pi * rates[lab] * t / SEQ_T + phases_c[lab] + rng.uniform(-0.6, 0.6))
        sig = mod * envelopes[lab][None, :]
        noise = rng.normal(scale=0.9, size=sig.shape)
        xs[i] = np.clip(sig + noise, -1.0, 1.0)
    y = labels.astype(np.int32)
    return (
        jnp.asarray(xs[:n_train]),
        jnp.asarray(y[:n_train]),
        jnp.asarray(xs[n_train:]),
        jnp.asarray(y[n_train:]),
    )


@partial(jax.jit, static_argnums=(2,))
def batch_iter_indices(key, n, batch):
    """One epoch of shuffled batch indices, dropped remainder."""
    perm = jax.random.permutation(key, n)
    nb = n // batch
    return perm[: nb * batch].reshape(nb, batch)
