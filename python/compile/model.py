"""L2: the model zoo — architecturally faithful, scaled-down versions of the
paper's four benchmarks (Section 5.1), expressed as a uniform layer graph.

* ``tds``       — Time-Depth-Separable speech blocks (Fig 2a): 1-D conv +
                  ReLU, FC + ReLU, FC without ReLU. No batch-norm (exercises
                  the plain dot-product → ReLU path).
* ``cnn10``     — ten conv3x3 + BN + ReLU layers (Fig 2b), the paper's CNN10.
* ``darknet19m``— nineteen conv layers in the Darknet19 3x3/1x1 alternating
                  pattern with maxpools, BN + ReLU (Fig 2b).
* ``resnet18m`` — residual basic blocks (Fig 2c): BN *and* residual
                  connections ahead of ReLU, the hardest case for the
                  predictor (both can flip the sign of the ReLU input).

Everything is NHWC; sequences are (T, 1, F) so one engine covers both
domains (the rust engine mirrors this exactly).

Two integer forward implementations share the layer graph (see quantize.py):
a pure-jnp one (calibration speed) and a Pallas-kernel one (the AOT
artifact); tests assert they agree bit-exactly in the integer domain.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------
# Layer graph
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Conv:
    """2-D convolution. kw=1 + w-dim-1 input makes it a 1-D (temporal) conv."""

    kh: int
    kw: int
    cout: int
    stride: int = 1
    pad: str = "same"  # 'same' | 'valid'
    bn: bool = False
    relu: bool = True
    res_from: Optional[int] = None  # node index whose float output is added pre-ReLU


@dataclass(frozen=True)
class FC:
    cout: int
    bn: bool = False
    relu: bool = True
    res_from: Optional[int] = None


@dataclass(frozen=True)
class MaxPool:
    size: int = 2


@dataclass(frozen=True)
class GAP:
    """Global average pool over H and W; output shape (N, 1, 1, C)."""


@dataclass(frozen=True)
class ReLUNode:
    """Standalone ReLU applied to the previous node's output (post-residual)."""


@dataclass(frozen=True)
class ModelDef:
    name: str
    input_shape: Tuple[int, int, int]  # (H, W, C)
    nodes: List[object] = field(default_factory=list)
    num_classes: int = 10

    def relu_layers(self) -> List[int]:
        """Indices of compute nodes whose output feeds a ReLU (predictable).

        A Conv/FC followed immediately by a standalone ReLUNode also counts
        (resnet blocks put the post-residual ReLU in its own node).
        """
        idxs = []
        for i, nd in enumerate(self.nodes):
            if not isinstance(nd, (Conv, FC)):
                continue
            if nd.relu:
                idxs.append(i)
            elif i + 1 < len(self.nodes) and isinstance(self.nodes[i + 1], ReLUNode):
                idxs.append(i)
        return idxs


# --------------------------------------------------------------------------
# Model definitions
# --------------------------------------------------------------------------


def tds() -> ModelDef:
    """3 TDS blocks (C=64) on (32, 1, 40) mel-like frames + classifier."""
    nodes: List[object] = [Conv(5, 1, 64, pad="same", relu=True)]  # entry conv
    for _ in range(3):
        nodes.append(Conv(5, 1, 64, pad="same", relu=True))  # temporal conv
        nodes.append(FC(64, relu=True))                      # pointwise FC
        nodes.append(FC(64, relu=False))                     # FC without ReLU
    nodes.append(GAP())
    nodes.append(FC(10, relu=False))
    return ModelDef("tds", (32, 1, 40), nodes)


def cnn10() -> ModelDef:
    """Ten conv3x3 + BN + ReLU (Fig 2b) on 16x16x3, then GAP + FC."""
    chans = [16, 16, 32, 32, 48, 48, 64, 64, 96, 96]
    strides = [1, 1, 2, 1, 1, 2, 1, 1, 1, 1]
    nodes: List[object] = [
        Conv(3, 3, c, stride=s, bn=True, relu=True) for c, s in zip(chans, strides)
    ]
    nodes.append(GAP())
    nodes.append(FC(10, relu=False))
    return ModelDef("cnn10", (16, 16, 3), nodes)


def darknet19m() -> ModelDef:
    """Darknet19's 3x3/1x1 alternation, channels scaled /8, 16x16 input."""
    nodes: List[object] = []

    def c3(c):
        nodes.append(Conv(3, 3, c, bn=True, relu=True))

    def c1(c):
        nodes.append(Conv(1, 1, c, bn=True, relu=True))

    c3(16)
    nodes.append(MaxPool(2))
    c3(32)
    nodes.append(MaxPool(2))
    c3(64), c1(32), c3(64)
    nodes.append(MaxPool(2))
    c3(96), c1(48), c3(96)
    c3(128), c1(64), c3(128), c1(64), c3(128)
    c3(160), c1(80), c3(160), c1(80), c3(160)
    nodes.append(Conv(1, 1, 10, bn=False, relu=False))  # darknet-style linear head
    nodes.append(GAP())
    return ModelDef("darknet19m", (16, 16, 3), nodes)


def resnet18m() -> ModelDef:
    """ResNet basic blocks (Fig 2c): 4 stages x 2 blocks, channels /4."""
    nodes: List[object] = [Conv(3, 3, 16, bn=True, relu=True)]  # stem

    def block(cout: int, stride: int):
        """[projection?] conv-bn-relu, conv-bn (+ residual), relu."""
        entry = len(nodes) - 1  # node producing the block input
        if stride != 1 or _node_cout(nodes[entry]) != cout:
            # projection shortcut: 1x1 conv + BN, no ReLU; consumes the same
            # input as the conv that follows it (see `consumes`).
            nodes.append(Conv(1, 1, cout, stride=stride, bn=True, relu=False))
            shortcut = len(nodes) - 1
        else:
            shortcut = entry
        nodes.append(Conv(3, 3, cout, stride=stride, bn=True, relu=True))
        nodes.append(Conv(3, 3, cout, bn=True, relu=False, res_from=shortcut))
        nodes.append(ReLUNode())

    for cout, stride in [(16, 1), (16, 1), (32, 2), (32, 1), (48, 2), (48, 1), (64, 2), (64, 1)]:
        block(cout, stride)
    nodes.append(GAP())
    nodes.append(FC(10, relu=False))
    return ModelDef("resnet18m", (16, 16, 3), nodes)


def _node_cout(nd) -> int:
    return nd.cout if isinstance(nd, (Conv, FC)) else -1


ZOO = {"tds": tds, "cnn10": cnn10, "darknet19m": darknet19m, "resnet18m": resnet18m}


# --------------------------------------------------------------------------
# Graph topology helpers (shared with quantize.py and mirrored in rust)
# --------------------------------------------------------------------------


def is_projection(mdef: ModelDef, i: int) -> bool:
    """Projection shortcuts: 1x1 Conv, no ReLU, referenced by a later res_from."""
    nd = mdef.nodes[i]
    if not (isinstance(nd, Conv) and nd.kh == 1 and nd.kw == 1 and not nd.relu):
        return False
    return any(getattr(other, "res_from", None) == i for other in mdef.nodes[i + 1 :])


def consumes(mdef: ModelDef, i: int) -> int:
    """Index of the node whose output node i consumes (-1 = model input).

    A projection shortcut is a *side branch*: it consumes the same input as
    the conv that follows it, so that conv skips over it in the chain.
    """
    if i == 0:
        return -1
    prev = i - 1
    if is_projection(mdef, prev):
        return prev - 1
    return prev


def input_of(mdef: ModelDef, i: int) -> int:
    """Like `consumes`, but for the projection node itself (same as next conv)."""
    if is_projection(mdef, i):
        return i - 1
    return consumes(mdef, i)


def node_shapes(mdef: ModelDef) -> List[Tuple[int, int, int]]:
    """Static (H, W, C) output shape of every node."""
    shapes: List[Tuple[int, int, int]] = []
    for i, nd in enumerate(mdef.nodes):
        src = input_of(mdef, i)
        h, w, c = mdef.input_shape if src == -1 else shapes[src]
        if isinstance(nd, Conv):
            if nd.pad == "same":
                h, w = -(-h // nd.stride), -(-w // nd.stride)
            else:
                h = (h - nd.kh) // nd.stride + 1
                w = (w - nd.kw) // nd.stride + 1
            c = nd.cout
        elif isinstance(nd, FC):
            c = nd.cout
        elif isinstance(nd, MaxPool):
            h, w = h // nd.size, max(1, w // nd.size)
        elif isinstance(nd, GAP):
            h, w = 1, 1
        # ReLUNode keeps shape
        shapes.append((h, w, c))
    return shapes


def mac_counts(mdef: ModelDef) -> List[int]:
    """MACs per node (0 for non-compute nodes) — drives Fig 1/3 and the sim."""
    shapes = node_shapes(mdef)
    counts = []
    for i, nd in enumerate(mdef.nodes):
        src = input_of(mdef, i)
        in_shape = mdef.input_shape if src == -1 else shapes[src]
        if isinstance(nd, Conv):
            oh, ow, _ = shapes[i]
            counts.append(oh * ow * nd.cout * nd.kh * nd.kw * in_shape[2])
        elif isinstance(nd, FC):
            oh, ow, _ = shapes[i]
            counts.append(oh * ow * nd.cout * in_shape[2])
        else:
            counts.append(0)
    return counts


# --------------------------------------------------------------------------
# Parameters & initialisation
# --------------------------------------------------------------------------


def init_params(mdef: ModelDef, seed: int = 0):
    """He-init weights; BN starts at identity. Returns (params, bn_state)."""
    key = jax.random.PRNGKey(seed)
    shapes = node_shapes(mdef)
    params, state = [], []
    for i, nd in enumerate(mdef.nodes):
        src = input_of(mdef, i)
        cin = (mdef.input_shape if src == -1 else shapes[src])[2]
        p, s = {}, {}
        if isinstance(nd, Conv):
            key, k1 = jax.random.split(key)
            fan_in = nd.kh * nd.kw * cin
            p["w"] = jax.random.normal(k1, (nd.kh, nd.kw, cin, nd.cout)) * np.sqrt(
                2.0 / fan_in
            )
            if nd.bn:
                p["gamma"], p["beta"] = jnp.ones((nd.cout,)), jnp.zeros((nd.cout,))
                s["mu"], s["var"] = jnp.zeros((nd.cout,)), jnp.ones((nd.cout,))
        elif isinstance(nd, FC):
            key, k1 = jax.random.split(key)
            p["w"] = jax.random.normal(k1, (cin, nd.cout)) * np.sqrt(2.0 / cin)
            if nd.bn:
                p["gamma"], p["beta"] = jnp.ones((nd.cout,)), jnp.zeros((nd.cout,))
                s["mu"], s["var"] = jnp.zeros((nd.cout,)), jnp.ones((nd.cout,))
        params.append(p)
        state.append(s)
    return params, state


# --------------------------------------------------------------------------
# Float forward (training / fp32 eval)
# --------------------------------------------------------------------------


def forward(mdef: ModelDef, params, state, x, train: bool = False, momentum=0.9):
    """Batched float forward. x: (N,H,W,C). Returns (logits, new_state)."""
    outs: List[jax.Array] = []
    new_state = [dict(s) for s in state]
    for i, nd in enumerate(mdef.nodes):
        src = input_of(mdef, i)
        cur = x if src == -1 else outs[src]
        if isinstance(nd, Conv):
            pad = "SAME" if nd.pad == "same" else "VALID"
            v = jax.lax.conv_general_dilated(
                cur,
                params[i]["w"],
                (nd.stride, nd.stride),
                pad,
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            )
            v, new_state[i] = _bn(nd, params[i], state[i], v, train, momentum)
            if nd.res_from is not None:
                v = v + outs[nd.res_from]
            if nd.relu:
                v = jnp.maximum(v, 0.0)
        elif isinstance(nd, FC):
            v = jnp.einsum("nhwc,cf->nhwf", cur, params[i]["w"])
            v, new_state[i] = _bn(nd, params[i], state[i], v, train, momentum)
            if nd.res_from is not None:
                v = v + outs[nd.res_from]
            if nd.relu:
                v = jnp.maximum(v, 0.0)
        elif isinstance(nd, ReLUNode):
            v = jnp.maximum(cur, 0.0)
        elif isinstance(nd, MaxPool):
            kw = min(nd.size, cur.shape[2])
            v = jax.lax.reduce_window(
                cur, -jnp.inf, jax.lax.max, (1, nd.size, kw, 1), (1, nd.size, kw, 1), "VALID"
            )
        elif isinstance(nd, GAP):
            v = cur.mean(axis=(1, 2), keepdims=True)
        else:  # pragma: no cover
            raise TypeError(nd)
        outs.append(v)
    return outs[-1].reshape(x.shape[0], -1), new_state


def _bn(nd, p, s, v, train, momentum):
    if not getattr(nd, "bn", False):
        return v, dict(s)
    axes = tuple(range(v.ndim - 1))
    if train:
        mu = v.mean(axis=axes)
        var = v.var(axis=axes)
        new_s = {
            "mu": momentum * s["mu"] + (1 - momentum) * mu,
            "var": momentum * s["var"] + (1 - momentum) * var,
        }
    else:
        mu, var = s["mu"], s["var"]
        new_s = dict(s)
    vhat = (v - mu) / jnp.sqrt(var + 1e-5)
    return vhat * p["gamma"] + p["beta"], new_s
