"""AOT driver: train → quantize → calibrate → export artifacts.

Run once by ``make artifacts``; python never appears on the request path.
Per model it emits into ``artifacts/``:

* ``<model>.w.bin``          — quantized model (MORW, see artifacts_io.py)
* ``<model>.predictor.json`` — offline MoR parameters (c/m/b, clusters)
* ``<model>.data.bin``       — test + calibration splits (MORD)
* ``<model>_fwd.hlo.txt``    — integer deploy forward lowered to HLO *text*
                               (NOT .serialize(): the image's xla_extension
                               0.5.1 rejects jax>=0.5 64-bit-id protos; the
                               text parser reassigns ids — see
                               /opt/xla-example/README.md)
* ``meta.json``              — index + accuracies + MAC counts

Trained parameters are cached in ``artifacts/cache/<model>.npz`` keyed by a
config hash, so re-running is cheap unless the model zoo changes.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import artifacts_io, calibrate as C, model as M, quantize as Q, train as T


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is ESSENTIAL: the default printer elides
    # the baked weight tensors as `constant({...})`, which the rust-side
    # text parser silently reads as zeros — the artifact would "run" with
    # empty weights.
    return comp.as_hlo_text(print_large_constants=True)


def _zoo_hash(name: str, steps: int, seed: int) -> str:
    """Cache key: model definition + training hyperparameters + source."""
    h = hashlib.sha256()
    for dep in ("model.py", "datasets.py", "train.py"):
        h.update(open(os.path.join(os.path.dirname(__file__), dep), "rb").read())
    h.update(f"{name}|{steps}|{seed}".encode())
    return h.hexdigest()[:16]


def _save_cache(path: str, params, state, info, key: str):
    flat = {}
    for i, p in enumerate(params):
        for k, v in p.items():
            flat[f"p{i}_{k}"] = np.asarray(v)
    for i, s in enumerate(state):
        for k, v in s.items():
            flat[f"s{i}_{k}"] = np.asarray(v)
    flat["__info"] = np.frombuffer(json.dumps(info).encode(), dtype=np.uint8)
    flat["__key"] = np.frombuffer(key.encode(), dtype=np.uint8)
    np.savez(path, **flat)


def _load_cache(path: str, n_nodes: int, key: str):
    if not os.path.exists(path):
        return None
    z = np.load(path)
    if "__key" not in z.files or bytes(z["__key"]).decode() != key:
        return None
    params = [dict() for _ in range(n_nodes)]
    state = [dict() for _ in range(n_nodes)]
    for name in z.files:
        if name.startswith("__"):
            continue
        if name.startswith("p"):
            i, k = name[1:].split("_", 1)
            params[int(i)][k] = jnp.asarray(z[name])
        elif name.startswith("s"):
            i, k = name[1:].split("_", 1)
            state[int(i)][k] = jnp.asarray(z[name])
    info = json.loads(bytes(z["__info"]).decode())
    return params, state, info


def build_model(name: str, out_dir: str, steps: int, seed: int, skip_hlo: bool) -> dict:
    mdef = M.ZOO[name]()
    cache_dir = os.path.join(out_dir, "cache")
    os.makedirs(cache_dir, exist_ok=True)
    key = _zoo_hash(name, steps, seed)
    cache_path = os.path.join(cache_dir, f"{name}.npz")

    cached = _load_cache(cache_path, len(mdef.nodes), key)
    if cached is not None:
        params, state, info = cached
        print(f"  [{name}] using cached training (acc={info['test_accuracy']*100:.1f}%)")
    else:
        print(f"  [{name}] training {steps} steps ...")
        params, state, info = T.train_model(mdef, steps=steps, seed=seed)
        _save_cache(cache_path, params, state, info, key)

    calib_x, calib_y = T.calib_split(mdef)
    test_x, test_y = T.test_split(mdef)

    qm = Q.quantize(mdef, params, state, calib_x)

    # quantized accuracy (integer path) on the test split
    logits, _ = Q.quant_forward(qm, test_x)
    quant_acc = float((jnp.argmax(logits, axis=1) == test_y).mean())
    print(f"  [{name}] int8 top-1 = {quant_acc*100:.1f}% (fp32 {info['test_accuracy']*100:.1f}%)")

    # offline MoR stage — fit regressions on the first 96 calibration
    # samples; the last 32 stay untouched as the threshold-selection
    # holdout used by the rust side (predictor::choose_threshold)
    cal = C.calibrate(qm, calib_x[:96])

    # artifacts
    artifacts_io.write_weights(os.path.join(out_dir, f"{name}.w.bin"), qm)
    artifacts_io.write_data(
        os.path.join(out_dir, f"{name}.data.bin"), test_x, test_y, calib_x, calib_y
    )
    with open(os.path.join(out_dir, f"{name}.predictor.json"), "w") as f:
        json.dump(C.to_json_dict(cal), f)

    hlo_path = os.path.join(out_dir, f"{name}_fwd.hlo.txt")
    if not skip_hlo:
        t0 = time.time()
        spec = jax.ShapeDtypeStruct(mdef.input_shape, jnp.float32)
        lowered = jax.jit(lambda x: (Q.deploy_forward(qm, x),)).lower(spec)
        with open(hlo_path, "w") as f:
            f.write(to_hlo_text(lowered))
        print(f"  [{name}] lowered HLO in {time.time()-t0:.1f}s")

    return {
        "name": name,
        "weights": f"{name}.w.bin",
        "predictor": f"{name}.predictor.json",
        "data": f"{name}.data.bin",
        "hlo": f"{name}_fwd.hlo.txt",
        "input_shape": list(mdef.input_shape),
        "num_nodes": len(mdef.nodes),
        "relu_layers": mdef.relu_layers(),
        "macs_per_sample": int(sum(M.mac_counts(mdef))),
        "fp32_accuracy": info["test_accuracy"],
        "int8_accuracy": quant_acc,
        "train_steps": info["steps"],
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="tds,cnn10,darknet19m,resnet18m")
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-hlo", action="store_true", help="skip HLO lowering (fast dev loop)")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    metas = []
    for name in args.models.split(","):
        name = name.strip()
        print(f"[aot] building {name}")
        metas.append(build_model(name, args.out_dir, args.steps, args.seed, args.skip_hlo))

    meta = {"version": 1, "models": metas}
    with open(os.path.join(args.out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print(f"[aot] wrote {len(metas)} models to {args.out_dir}")


if __name__ == "__main__":
    main()
