"""Post-training symmetric int8 quantization + the integer deploy forward.

The paper's accelerator uses an 8-bit baseline precision (Table 1: "CU
precision 8 b") with int32 partial-sum registers. We quantize post-training:

* weights: per-layer symmetric, ``sw = max|w| / 127`` → int8;
* activations: per-layer-input symmetric, ``sx`` from the 99.9th percentile
  of |input| over the calibration subset → int8 with saturation.
* batch-norm folded to an affine (scale, shift) from the running statistics:
  ``relu_in = (dot * sw * sx) * bn_scale + bn_shift (+ residual)``.

Dataflow contract (mirrored bit-for-bit by rust/src/engine):

* activations travel between nodes as *float32*;
* every compute node quantizes its own input with its ``sx``;
* integer dot products are exact (int8 x int8 → int32);
* everything after the dot product (dequant, BN, residual, ReLU, GAP) is
  float32 with the same operation order.

``quant_forward`` (pure jnp) is the fast path used for calibration and
accuracy eval; ``deploy_forward`` routes the dot products through the Pallas
kernels and is what ``aot.py`` lowers to the HLO artifact. A pytest asserts
both agree exactly in the integer domain.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .kernels import conv2d as kconv
from .kernels import int8_matmul as kmm


@dataclass
class QuantLayer:
    """Quantized parameters for one compute node (Conv/FC)."""

    w_int8: np.ndarray            # conv: (KH,KW,CIN,COUT); fc: (CIN,COUT)
    sw: float                     # weight scale
    sx: float                     # input activation scale
    bn_scale: Optional[np.ndarray]  # (COUT,) folded, None if no BN
    bn_shift: Optional[np.ndarray]


@dataclass
class QuantModel:
    mdef: M.ModelDef
    layers: Dict[int, QuantLayer]  # keyed by node index
    sx0: float                     # model-input scale

    def num_neurons(self, i: int) -> int:
        nd = self.mdef.nodes[i]
        return nd.cout


def quantize(
    mdef: M.ModelDef, params, state, calib_x: jax.Array, pct: float = 99.9
) -> QuantModel:
    """Fold BN, pick scales from the calibration batch, quantize weights."""
    # 1. collect float activations at every node input to pick sx
    outs = _float_node_outputs(mdef, params, state, calib_x)
    layers: Dict[int, QuantLayer] = {}
    sx0 = _scale_of(calib_x, pct)
    for i, nd in enumerate(mdef.nodes):
        if not isinstance(nd, (M.Conv, M.FC)):
            continue
        src = M.input_of(mdef, i)
        x_in = calib_x if src == -1 else outs[src]
        sx = _scale_of(x_in, pct)
        w = np.asarray(params[i]["w"])
        sw = float(np.abs(w).max() / 127.0) or 1.0
        w_int8 = np.clip(np.round(w / sw), -127, 127).astype(np.int8)
        bn_scale = bn_shift = None
        if nd.bn:
            gamma = np.asarray(params[i]["gamma"])
            beta = np.asarray(params[i]["beta"])
            mu = np.asarray(state[i]["mu"])
            var = np.asarray(state[i]["var"])
            bn_scale = (gamma / np.sqrt(var + 1e-5)).astype(np.float32)
            bn_shift = (beta - mu * bn_scale).astype(np.float32)
        layers[i] = QuantLayer(w_int8, sw, sx, bn_scale, bn_shift)
    return QuantModel(mdef, layers, sx0)


def _scale_of(x, pct: float) -> float:
    a = np.asarray(jnp.abs(x))
    v = float(np.percentile(a, pct))
    return (v / 127.0) or 1.0


def _float_node_outputs(mdef, params, state, x) -> List[jax.Array]:
    # M.forward doesn't expose node outputs; inline a capture version
    outs: List[jax.Array] = []
    for i, nd in enumerate(mdef.nodes):
        src = M.input_of(mdef, i)
        cur = x if src == -1 else outs[src]
        if isinstance(nd, M.Conv):
            pad = "SAME" if nd.pad == "same" else "VALID"
            v = jax.lax.conv_general_dilated(
                cur, params[i]["w"], (nd.stride, nd.stride), pad,
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            )
            v, _ = M._bn(nd, params[i], state[i], v, False, 0.9)
            if nd.res_from is not None:
                v = v + outs[nd.res_from]
            if nd.relu:
                v = jnp.maximum(v, 0.0)
        elif isinstance(nd, M.FC):
            v = jnp.einsum("nhwc,cf->nhwf", cur, params[i]["w"])
            v, _ = M._bn(nd, params[i], state[i], v, False, 0.9)
            if nd.res_from is not None:
                v = v + outs[nd.res_from]
            if nd.relu:
                v = jnp.maximum(v, 0.0)
        elif isinstance(nd, M.ReLUNode):
            v = jnp.maximum(cur, 0.0)
        elif isinstance(nd, M.MaxPool):
            kw = min(nd.size, cur.shape[2])
            v = jax.lax.reduce_window(
                cur, -jnp.inf, jax.lax.max, (1, nd.size, kw, 1), (1, nd.size, kw, 1), "VALID"
            )
        elif isinstance(nd, M.GAP):
            v = cur.mean(axis=(1, 2), keepdims=True)
        outs.append(v)
    return outs


# --------------------------------------------------------------------------
# Integer forward (pure jnp) — calibration/eval fast path
# --------------------------------------------------------------------------


def quantize_act(x: jax.Array, sx: float) -> jax.Array:
    return jnp.clip(jnp.round(x / sx), -127, 127).astype(jnp.int8)


def quant_forward(
    qm: QuantModel, x: jax.Array, collect: bool = False
) -> Tuple[jax.Array, Dict[int, Tuple[jax.Array, jax.Array]]]:
    """Integer forward on a float batch x (N,H,W,C).

    Returns (logits, taps); when ``collect`` is True, taps[i] holds, for
    every ReLU compute node i, a pair of (N*OH*OW, COUT) float32 matrices:
    (binary dot product counts, dequantized base dot products pre-BN) — the
    raw series the offline regression fits (Section 3.2.1).
    """
    mdef = qm.mdef
    outs: List[jax.Array] = []
    taps: Dict[int, Tuple[jax.Array, jax.Array]] = {}
    relu_set = set(mdef.relu_layers())
    for i, nd in enumerate(mdef.nodes):
        src = M.input_of(mdef, i)
        cur = x if src == -1 else outs[src]
        if isinstance(nd, (M.Conv, M.FC)):
            ql = qm.layers[i]
            xq = quantize_act(cur, ql.sx)
            wq = jnp.asarray(ql.w_int8)
            if isinstance(nd, M.Conv):
                pad = "SAME" if nd.pad == "same" else "VALID"
                dot = jax.lax.conv_general_dilated(
                    xq, wq, (nd.stride, nd.stride), pad,
                    dimension_numbers=("NHWC", "HWIO", "NHWC"),
                    preferred_element_type=jnp.int32,
                )
                if collect and i in relu_set:
                    xs = jnp.where(xq > 0, jnp.int8(1), jnp.int8(-1))
                    ws = jnp.where(wq >= 0, jnp.int8(1), jnp.int8(-1))
                    pbin = jax.lax.conv_general_dilated(
                        xs, ws, (nd.stride, nd.stride), pad,
                        dimension_numbers=("NHWC", "HWIO", "NHWC"),
                        preferred_element_type=jnp.int32,
                    )
                    # The conv zero-pads the *already binarized* tensor, so
                    # SAME-padding border lanes contribute 0 to p_bin (they
                    # also contribute 0 to the base dot). The rust engine
                    # reproduces this: binarized padding cells are 0, interior
                    # cells are ±1.
                    taps[i] = (
                        pbin.reshape(-1, nd.cout).astype(jnp.float32),
                        dot.reshape(-1, nd.cout).astype(jnp.float32)
                        * (ql.sw * ql.sx),
                    )
            else:
                dot = jax.lax.dot_general(
                    xq, wq, (((3,), (0,)), ((), ())),
                    preferred_element_type=jnp.int32,
                )
                if collect and i in relu_set:
                    xs = jnp.where(xq > 0, jnp.int8(1), jnp.int8(-1))
                    ws = jnp.where(wq >= 0, jnp.int8(1), jnp.int8(-1))
                    pbin = jax.lax.dot_general(
                        xs, ws, (((3,), (0,)), ((), ())),
                        preferred_element_type=jnp.int32,
                    )
                    taps[i] = (
                        pbin.reshape(-1, nd.cout).astype(jnp.float32),
                        dot.reshape(-1, nd.cout).astype(jnp.float32)
                        * (ql.sw * ql.sx),
                    )
            v = dot.astype(jnp.float32) * (ql.sw * ql.sx)
            if ql.bn_scale is not None:
                v = v * jnp.asarray(ql.bn_scale) + jnp.asarray(ql.bn_shift)
            if nd.res_from is not None:
                v = v + outs[nd.res_from]
            if nd.relu:
                v = jnp.maximum(v, 0.0)
        elif isinstance(nd, M.ReLUNode):
            v = jnp.maximum(cur, 0.0)
        elif isinstance(nd, M.MaxPool):
            kw = min(nd.size, cur.shape[2])
            v = jax.lax.reduce_window(
                cur, -jnp.inf, jax.lax.max, (1, nd.size, kw, 1), (1, nd.size, kw, 1), "VALID"
            )
        elif isinstance(nd, M.GAP):
            v = cur.mean(axis=(1, 2), keepdims=True)
        outs.append(v)
    return outs[-1].reshape(x.shape[0], -1), taps


# --------------------------------------------------------------------------
# Deploy forward (Pallas kernels) — the function aot.py lowers to HLO
# --------------------------------------------------------------------------


def deploy_forward(qm: QuantModel, x: jax.Array) -> jax.Array:
    """Single-sample integer forward through the Pallas kernels.

    x: (H, W, C) float32. Returns (num_classes,) float32 logits. The conv
    dot products run on kernels.conv2d/int8_matmul so that the lowered HLO
    artifact contains the L1 kernels (interpret=True lowers them to plain
    HLO ops executable by the rust PJRT CPU client).
    """
    mdef = qm.mdef
    outs: List[jax.Array] = []
    for i, nd in enumerate(mdef.nodes):
        src = M.input_of(mdef, i)
        cur = x if src == -1 else outs[src]
        if isinstance(nd, (M.Conv, M.FC)):
            ql = qm.layers[i]
            xq = quantize_act(cur, ql.sx)
            wq = jnp.asarray(ql.w_int8)
            if isinstance(nd, M.Conv):
                if nd.pad == "same":
                    ph = _same_pad(cur.shape[0], nd.kh, nd.stride)
                    pw = _same_pad(cur.shape[1], nd.kw, nd.stride)
                    xq = jnp.pad(xq, (ph, pw, (0, 0)))
                dot = kconv.conv2d_int8(xq, wq, stride=nd.stride)
            else:
                h, w, c = cur.shape
                dot = kmm.int8_matmul(xq.reshape(h * w, c), wq).reshape(h, w, nd.cout)
            v = dot.astype(jnp.float32) * (ql.sw * ql.sx)
            if ql.bn_scale is not None:
                v = v * jnp.asarray(ql.bn_scale) + jnp.asarray(ql.bn_shift)
            if nd.res_from is not None:
                v = v + outs[nd.res_from]
            if nd.relu:
                v = jnp.maximum(v, 0.0)
        elif isinstance(nd, M.ReLUNode):
            v = jnp.maximum(cur, 0.0)
        elif isinstance(nd, M.MaxPool):
            kw2 = min(nd.size, cur.shape[1])
            v = jax.lax.reduce_window(
                cur, -jnp.inf, jax.lax.max, (nd.size, kw2, 1), (nd.size, kw2, 1), "VALID"
            )
        elif isinstance(nd, M.GAP):
            v = cur.mean(axis=(0, 1), keepdims=True)
        outs.append(v)
    return outs[-1].reshape(-1)


def _same_pad(size: int, k: int, stride: int) -> Tuple[int, int]:
    out = -(-size // stride)
    total = max(0, (out - 1) * stride + k - size)
    return total // 2, total - total // 2
