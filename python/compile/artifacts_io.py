"""Binary artifact formats shared with the rust loaders (rust/src/model).

Two custom little-endian formats (no numpy/serde on the rust side):

``<model>.w.bin`` — MORW v1, the quantized model:

    magic   4 bytes  b"MORW"
    version u32      1
    n_nodes u32
    sx0     f32      model-input activation scale
    then per node:
      kind      u8   0=conv 1=fc 2=maxpool 3=gap 4=relu
      flags     u8   bit0 relu, bit1 bn
      res_from  i32  node index whose float output is added pre-ReLU (-1 none)
      consumes  i32  node index whose output this node reads (-1 = input)
      conv: kh,kw,cin,cout,stride u32 x5, pad u8 (1=same), sw f32, sx f32,
            weights i8[kh*kw*cin*cout] in (KH,KW,CIN,COUT) row-major,
            if bn: scale f32[cout], shift f32[cout]
      fc:   cin,cout u32 x2, sw f32, sx f32, weights i8[cin*cout] (CIN,COUT),
            if bn: scale f32[cout], shift f32[cout]
      maxpool: size u32
      gap/relu: no payload

``<model>.data.bin`` — MORD v1, evaluation data:

    magic b"MORD", version u32 1, n_test u32, n_calib u32, h,w,c u32 x3,
    test_x f32[n_test*h*w*c], test_y u16[n_test],
    calib_x f32[n_calib*h*w*c], calib_y u16[n_calib]
"""

from __future__ import annotations

import struct
from typing import List

import numpy as np

from . import model as M
from . import quantize as Q

KIND_CONV, KIND_FC, KIND_MAXPOOL, KIND_GAP, KIND_RELU = 0, 1, 2, 3, 4


def write_weights(path: str, qm: Q.QuantModel) -> None:
    mdef = qm.mdef
    out = bytearray()
    out += b"MORW"
    out += struct.pack("<II", 1, len(mdef.nodes))
    out += struct.pack("<f", qm.sx0)
    for i, nd in enumerate(mdef.nodes):
        res_from = getattr(nd, "res_from", None)
        res_from = -1 if res_from is None else res_from
        consumes = M.input_of(mdef, i)
        if isinstance(nd, M.Conv):
            ql = qm.layers[i]
            flags = (1 if nd.relu else 0) | (2 if nd.bn else 0)
            out += struct.pack("<BBii", KIND_CONV, flags, res_from, consumes)
            kh, kw, cin, cout = ql.w_int8.shape
            out += struct.pack("<5IB", kh, kw, cin, cout, nd.stride, 1 if nd.pad == "same" else 0)
            out += struct.pack("<ff", ql.sw, ql.sx)
            out += ql.w_int8.tobytes()  # row-major (KH,KW,CIN,COUT)
            if nd.bn:
                out += ql.bn_scale.astype("<f4").tobytes()
                out += ql.bn_shift.astype("<f4").tobytes()
        elif isinstance(nd, M.FC):
            ql = qm.layers[i]
            flags = (1 if nd.relu else 0) | (2 if nd.bn else 0)
            out += struct.pack("<BBii", KIND_FC, flags, res_from, consumes)
            cin, cout = ql.w_int8.shape
            out += struct.pack("<II", cin, cout)
            out += struct.pack("<ff", ql.sw, ql.sx)
            out += ql.w_int8.tobytes()
            if nd.bn:
                out += ql.bn_scale.astype("<f4").tobytes()
                out += ql.bn_shift.astype("<f4").tobytes()
        elif isinstance(nd, M.MaxPool):
            out += struct.pack("<BBii", KIND_MAXPOOL, 0, -1, consumes)
            out += struct.pack("<I", nd.size)
        elif isinstance(nd, M.GAP):
            out += struct.pack("<BBii", KIND_GAP, 0, -1, consumes)
        elif isinstance(nd, M.ReLUNode):
            out += struct.pack("<BBii", KIND_RELU, 0, -1, consumes)
        else:  # pragma: no cover
            raise TypeError(nd)
    with open(path, "wb") as f:
        f.write(bytes(out))


def write_data(path: str, test_x, test_y, calib_x, calib_y) -> None:
    tx = np.asarray(test_x, dtype="<f4")
    cx = np.asarray(calib_x, dtype="<f4")
    ty = np.asarray(test_y, dtype="<u2")
    cy = np.asarray(calib_y, dtype="<u2")
    n_test, h, w, c = tx.shape
    n_calib = cx.shape[0]
    with open(path, "wb") as f:
        f.write(b"MORD")
        f.write(struct.pack("<IIIIII", 1, n_test, n_calib, h, w, c))
        f.write(tx.tobytes())
        f.write(ty.tobytes())
        f.write(cx.tobytes())
        f.write(cy.tobytes())


def read_weights_header(path: str) -> List[dict]:
    """Debug/test helper: parse MORW back into dicts (not used at runtime)."""
    with open(path, "rb") as f:
        buf = f.read()
    assert buf[:4] == b"MORW"
    ver, n_nodes = struct.unpack_from("<II", buf, 4)
    assert ver == 1
    (sx0,) = struct.unpack_from("<f", buf, 12)
    off = 16
    nodes = []
    for _ in range(n_nodes):
        kind, flags, res_from, consumes = struct.unpack_from("<BBii", buf, off)
        off += 10
        node = {"kind": kind, "flags": flags, "res_from": res_from, "consumes": consumes}
        if kind == KIND_CONV:
            kh, kw, cin, cout, stride, pad = struct.unpack_from("<5IB", buf, off)
            off += 21
            sw, sx = struct.unpack_from("<ff", buf, off)
            off += 8
            nw = kh * kw * cin * cout
            node.update(kh=kh, kw=kw, cin=cin, cout=cout, stride=stride, pad=pad, sw=sw, sx=sx)
            node["w"] = np.frombuffer(buf, np.int8, nw, off).reshape(kh, kw, cin, cout)
            off += nw
            if flags & 2:
                node["bn_scale"] = np.frombuffer(buf, "<f4", cout, off)
                off += 4 * cout
                node["bn_shift"] = np.frombuffer(buf, "<f4", cout, off)
                off += 4 * cout
        elif kind == KIND_FC:
            cin, cout = struct.unpack_from("<II", buf, off)
            off += 8
            sw, sx = struct.unpack_from("<ff", buf, off)
            off += 8
            node.update(cin=cin, cout=cout, sw=sw, sx=sx)
            node["w"] = np.frombuffer(buf, np.int8, cin * cout, off).reshape(cin, cout)
            off += cin * cout
            if flags & 2:
                node["bn_scale"] = np.frombuffer(buf, "<f4", cout, off)
                off += 4 * cout
                node["bn_shift"] = np.frombuffer(buf, "<f4", cout, off)
                off += 4 * cout
        elif kind == KIND_MAXPOOL:
            (node["size"],) = struct.unpack_from("<I", buf, off)
            off += 4
        nodes.append(node)
    assert off == len(buf), (off, len(buf))
    return nodes
