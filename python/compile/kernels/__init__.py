"""Pallas kernels (L1) for the Mixture-of-Rookies reproduction.

All kernels lower with interpret=True (CPU PJRT cannot run Mosaic
custom-calls); `ref.py` holds the pure-jnp oracles the tests check against.
"""

from . import binary_dot, conv2d, int8_matmul, mor_dense, ref  # noqa: F401
