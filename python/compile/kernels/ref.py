"""Pure-jnp oracles for every Pallas kernel in this package.

These are the *correctness contracts*: each kernel's pytest sweeps shapes and
dtypes with hypothesis and asserts exact (integer) or allclose (float)
agreement against the function of the same name here.

Conventions shared with the rust functional engine (rust/src/engine):

* "base precision" dot product: int8 x int8 accumulated in int32.
* "binary" dot product: sign(w)·act(x) with
      sign(w) := +1 if w >= 0 else -1   (the literal sign bit), and
      act(x)  := +1 if x >  0 else -1   (active / inactive).
  i.e. p_bin in [-K, K] for K-element vectors. The asymmetric zero handling
  matters: most layer inputs are post-ReLU and therefore non-negative, so a
  ">= 0" activation convention would binarize every input to +1 and make
  p_bin a constant (zero correlation). Treating exact zeros as "inactive"
  (-1) preserves the information ReLU sparsity carries — this is what makes
  the paper's self-correlation (Fig 4/5) reproducible on post-ReLU layers.
* fitted line: p̂_base = m * p_bin + b, in dequantized (float) units.
* MoR skip rule: a neuron output is forced to zero iff the *estimated* ReLU
  input (after batch-norm / residual) is negative.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """(M,K) int8 @ (K,N) int8 -> (M,N) int32."""
    return jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


def sign_pm1(v: jax.Array) -> jax.Array:
    """Weight binarization: +1 for v >= 0 else -1 (the literal sign bit)."""
    return jnp.where(v >= 0, jnp.int8(1), jnp.int8(-1))


def act_pm1(v: jax.Array) -> jax.Array:
    """Activation binarization: +1 for v > 0 else -1 (active/inactive)."""
    return jnp.where(v > 0, jnp.int8(1), jnp.int8(-1))


def binary_dot(x: jax.Array, w: jax.Array) -> jax.Array:
    """Binary (±1) dot products: (M,K) x (K,N) int8 -> (M,N) int32.

    Equivalent to K - 2*popcount(activebit(x) XOR signbit(w)) per pair.
    """
    return int8_matmul(act_pm1(x), sign_pm1(w))


def fitted_line(p_bin: jax.Array, m: jax.Array, b: jax.Array) -> jax.Array:
    """Per-neuron affine map from binary dot product to estimated base dot."""
    return p_bin.astype(jnp.float32) * m[None, :] + b[None, :]


def bn_affine(v: jax.Array, scale: jax.Array, shift: jax.Array) -> jax.Array:
    """Folded batch-norm: v*scale + shift (scale = gamma/sigma, shift = beta - mu*gamma/sigma)."""
    return v * scale[None, :] + shift[None, :]


def mor_dense(
    x: jax.Array,          # (M, K) int8 activations
    w: jax.Array,          # (K, N) int8 weights
    m: jax.Array,          # (N,) fitted-line slope (dequant units per bin-count)
    b: jax.Array,          # (N,) fitted-line intercept
    bn_scale: jax.Array,   # (N,) folded BN scale (ones if no BN)
    bn_shift: jax.Array,   # (N,) folded BN shift (zeros if no BN)
    residual: jax.Array,   # (M, N) float residual input (zeros if none)
    enabled: jax.Array,    # (N,) bool: predictor enabled for this neuron (c >= T)
    dq: float,             # dequant scale: float_value = dq * int32_dot
):
    """Fused MoR-predicted dense layer (the paper's online stage for one layer).

    Returns (y, skipped):
      y        (M,N) float32 — post-BN, post-residual, post-ReLU outputs, with
               predicted-zero neurons forced to 0.0
      skipped  (M,N) bool    — True where the prediction skipped the neuron.

    The oracle computes the full dot product everywhere and then applies the
    skip mask; hardware (and the rust engine) skips the computation itself.
    """
    p_bin = binary_dot(x, w)
    est_dot = fitted_line(p_bin, m, b)                    # dequant units
    est_relu_in = bn_affine(est_dot, bn_scale, bn_shift) + residual
    skip = jnp.logical_and(est_relu_in < 0.0, enabled[None, :])

    full = int8_matmul(x, w).astype(jnp.float32) * dq
    relu_in = bn_affine(full, bn_scale, bn_shift) + residual
    y = jnp.maximum(relu_in, 0.0)
    y = jnp.where(skip, 0.0, y)
    return y, skip


def im2col(x: jax.Array, kh: int, kw: int, stride: int) -> jax.Array:
    """(H,W,C) -> (OH*OW, KH*KW*C) patches, VALID padding, row-major windows."""
    h, w, c = x.shape
    oh = (h - kh) // stride + 1
    ow = (w - kw) // stride + 1
    idx_h = (jnp.arange(oh) * stride)[:, None, None, None]
    idx_w = (jnp.arange(ow) * stride)[None, :, None, None]
    off_h = jnp.arange(kh)[None, None, :, None]
    off_w = jnp.arange(kw)[None, None, None, :]
    patches = x[idx_h + off_h, idx_w + off_w]  # (OH, OW, KH, KW, C)
    return patches.reshape(oh * ow, kh * kw * c)


def conv2d_int8(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    """int8 conv via im2col: x (H,W,C), w (KH,KW,C,F) -> (OH,OW,F) int32."""
    kh, kw, c, f = w.shape
    cols = im2col(x, kh, kw, stride)                   # (P, KH*KW*C)
    wmat = w.reshape(kh * kw * c, f)                   # (KH*KW*C, F)
    out = int8_matmul(cols, wmat)                      # (P, F)
    h = x.shape[0]
    oh = (h - kh) // stride + 1
    ow = (x.shape[1] - kw) // stride + 1
    return out.reshape(oh, ow, f)
