"""L1 Pallas kernel: int8 im2col convolution.

CONV layers dominate three of the paper's four benchmarks (Fig 3). On the
accelerator a CONV output is "a dot product between a filter and an input
window" — identical to an FC neuron except for input reuse, which the Row
Controller exploits by loading input blocks with stride awareness
(Section 4.1). The TPU-shaped equivalent is im2col: patches are gathered
once (a cheap gather at these sizes) and every output pixel becomes a row
of a single MXU matmul, so one weight fetch is amortised across the whole
feature map — the same reuse the input SRAM provides on the ASIC.

The patch gather happens at the jnp level (it lowers to a static gather);
the hot matmul is the tiled Pallas kernel from ``int8_matmul``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import int8_matmul as mm


def im2col(x: jax.Array, kh: int, kw: int, stride: int) -> jax.Array:
    """(H,W,C) -> (OH*OW, KH*KW*C) int8 patch matrix, VALID padding."""
    h, w, c = x.shape
    oh = (h - kh) // stride + 1
    ow = (w - kw) // stride + 1
    idx_h = (jnp.arange(oh) * stride)[:, None, None, None]
    idx_w = (jnp.arange(ow) * stride)[None, :, None, None]
    off_h = jnp.arange(kh)[None, None, :, None]
    off_w = jnp.arange(kw)[None, None, None, :]
    patches = x[idx_h + off_h, idx_w + off_w]
    return patches.reshape(oh * ow, kh * kw * c)


@functools.partial(jax.jit, static_argnames=("stride", "bm", "bn", "bk"))
def conv2d_int8(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    bm: int = mm.DEFAULT_BM,
    bn: int = mm.DEFAULT_BN,
    bk: int = mm.DEFAULT_BK,
) -> jax.Array:
    """int8 VALID conv: x (H,W,C), w (KH,KW,C,F) -> (OH,OW,F) int32."""
    assert x.dtype == jnp.int8 and w.dtype == jnp.int8
    kh, kw, c, f = w.shape
    cols = im2col(x, kh, kw, stride)
    wmat = w.reshape(kh * kw * c, f)
    out = mm.int8_matmul(cols, wmat, bm=bm, bn=bn, bk=bk)
    oh = (x.shape[0] - kh) // stride + 1
    ow = (x.shape[1] - kw) // stride + 1
    return out.reshape(oh, ow, f)
