"""L1 Pallas kernel: tiled int8 x int8 -> int32 matmul.

This is the base-precision dot-product engine of the accelerator's CUs
(Section 4.3 of the paper), expressed for a TPU-class memory hierarchy:

* the grid walks (M/BM, N/BN, K/BK) tiles;
* each (BM,BK) activation tile and (BK,BN) weight tile is staged into VMEM
  by the BlockSpecs (the HBM->VMEM schedule the paper's Row Controller
  implements with "input blocks" in the input SRAM);
* partials accumulate in an int32 VMEM scratch-free pattern: the output
  block is revisited once per K-step and accumulated in place (dimension
  semantics: K is the innermost, "arbitrary" grid axis).

On a real TPU the inner ``dot_general`` maps onto the MXU with int8 inputs
and int32 accumulation. We lower with ``interpret=True`` (CPU PJRT cannot
execute Mosaic custom-calls); the tiling is still the real schedule and is
what the VMEM/MXU estimates in DESIGN.md §7 are computed from.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: MXU-friendly multiples of (8,128) that keep
# BM*BK + BK*BN int8 bytes + BM*BN int32 bytes well under ~128 KiB of VMEM.
DEFAULT_BM = 32
DEFAULT_BN = 64
DEFAULT_BK = 64


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (BM,BN) output tile: accumulate the current K-slab."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        x_ref[...],
        w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def _pad_to(a: jax.Array, m0: int, m1: int) -> jax.Array:
    p0 = (-a.shape[0]) % m0
    p1 = (-a.shape[1]) % m1
    if p0 or p1:
        a = jnp.pad(a, ((0, p0), (0, p1)))
    return a


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def int8_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
) -> jax.Array:
    """(M,K) int8 @ (K,N) int8 -> (M,N) int32, tiled Pallas matmul.

    Shapes need not be tile-aligned; inputs are zero-padded (zeros contribute
    nothing to integer dot products) and the result is sliced back.
    """
    assert x.dtype == jnp.int8 and w.dtype == jnp.int8, (x.dtype, w.dtype)
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)

    bm_ = min(bm, _ceil_mult(m, 8))
    bn_ = min(bn, _ceil_mult(n, 8))
    bk_ = min(bk, _ceil_mult(k, 8))
    xp = _pad_to(x, bm_, bk_)
    wp = _pad_to(w, bk_, bn_)
    mp, kp = xp.shape
    _, np_ = wp.shape

    grid = (mp // bm_, np_ // bn_, kp // bk_)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk_, bn_), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


def _ceil_mult(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


def vmem_bytes(bm: int = DEFAULT_BM, bn: int = DEFAULT_BN, bk: int = DEFAULT_BK) -> int:
    """VMEM working-set estimate for one grid step (int8 in, int32 acc)."""
    return bm * bk + bk * bn + 4 * bm * bn
