"""L1 Pallas kernel: binary (±1) dot products — the paper's binCU array.

The accelerator's Binary Prediction Unit (Section 4.4) computes the 1-bit
dot product with XNOR + popcount gates. That is an ASIC/CPU idiom; on a
TPU-class target the natural mapping (DESIGN.md §Hardware-Adaptation) is:

* take the *sign bit* of the int8 activations and weights (zero counts as
  positive — the literal sign bit of two's complement),
* map bits to ±1 int8 values in VMEM,
* feed an MXU-shaped int8 matmul with int32 accumulation.

The ±1 matmul is numerically identical to ``K - 2*popcount(xor)`` and costs
one MXU pass at 1/1 the int8 rate — the "cheapness" the paper gets from
XNOR gates we get from skipping the full-precision *weight fetch*: sign bits
travel as part of the packed weights and the binary pass touches 8x less
HBM per weight element when packed (the rust engine packs them into u64
words; here the HLO-level contract is the ±1 matmul itself).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import int8_matmul as mm


def _binary_kernel(x_ref, w_ref, o_ref):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # activations: active/inactive (+1 iff > 0); weights: sign bit (+1 iff >= 0)
    xs = jnp.where(x_ref[...] > 0, jnp.int8(1), jnp.int8(-1))
    ws = jnp.where(w_ref[...] >= 0, jnp.int8(1), jnp.int8(-1))
    o_ref[...] += jax.lax.dot_general(
        xs, ws, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def binary_dot(
    x: jax.Array,
    w: jax.Array,
    *,
    bm: int = mm.DEFAULT_BM,
    bn: int = mm.DEFAULT_BN,
    bk: int = mm.DEFAULT_BK,
) -> jax.Array:
    """(M,K) int8 x (K,N) int8 -> (M,N) int32 of sign(x)·sign(w) products.

    NOTE on padding: padded K-lanes must contribute a known constant.
    We pad activations with +1 (act(+1) = +1) and weights with 0
    (sign(0) = +1), so each padded lane adds exactly +1·+1 = +1 to every
    output element; the pad count is subtracted afterwards.
    """
    assert x.dtype == jnp.int8 and w.dtype == jnp.int8
    m, k = x.shape
    k2, n = w.shape
    assert k == k2

    bm_ = min(bm, _ceil(m, 8))
    bn_ = min(bn, _ceil(n, 8))
    bk_ = min(bk, _ceil(k, 8))
    pad_k = (-k) % bk_
    xp = jnp.pad(x, (((0, (-m) % bm_), (0, pad_k))), constant_values=1)
    wp = jnp.pad(w, (((0, pad_k), (0, (-n) % bn_))))
    mp, kp = xp.shape
    _, np_ = wp.shape

    grid = (mp // bm_, np_ // bn_, kp // bk_)
    out = pl.pallas_call(
        _binary_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk_, bn_), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=True,
    )(xp, wp)
    # each padded K-lane contributed (+1)*(+1) = +1 to every output element
    return out[:m, :n] - jnp.int32(pad_k)


def _ceil(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m
