"""L1 Pallas kernel: fused Mixture-of-Rookies predicted dense layer.

This is the paper's *online stage* for one FC layer, fused into a single
VMEM-resident pipeline so the prediction never round-trips to HBM:

    1. binary (±1) dot product of the activation tile and weight tile,
    2. per-neuron fitted line  p̂ = m·p_bin + b   (dequant units),
    3. batch-norm affine + residual on the estimate,
    4. skip mask = (estimate < 0) AND (predictor enabled for neuron),
    5. full int8 dot product, BN/residual/ReLU,
    6. outputs where the mask fired are forced to 0.

On the ASIC, step 5 is *physically skipped* for masked neurons (that is the
whole point); in a dense-tensor HLO we compute everywhere and mask, which
keeps the artifact a faithful *functional* model — the cycle-level savings
are measured by the rust simulator, which interprets the same mask.

Layout: grid walks (M/BM, N/BN); K is kept whole inside the kernel
(per-layer K in this repo's model zoo is <= 1152, so an int8 (BM,K) slab +
(K,BN) weights + int32 accumulators fit comfortably in VMEM; see
``vmem_bytes``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 32
DEFAULT_BN = 64


def _mor_dense_kernel(
    x_ref, w_ref, m_ref, b_ref, scale_ref, shift_ref, res_ref, en_ref, dq_ref,
    y_ref, skip_ref,
):
    x = x_ref[...]
    w = w_ref[...]

    # -- predictor path (binCU): ±1 matmul + fitted line ------------------
    # activations: active/inactive (+1 iff > 0); weights: sign bit (+1 iff >= 0)
    xs = jnp.where(x > 0, jnp.int8(1), jnp.int8(-1))
    ws = jnp.where(w >= 0, jnp.int8(1), jnp.int8(-1))
    p_bin = jax.lax.dot_general(
        xs, ws, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    ).astype(jnp.float32)
    est = p_bin * m_ref[...][None, :] + b_ref[...][None, :]
    est = est * scale_ref[...][None, :] + shift_ref[...][None, :] + res_ref[...]
    skip = jnp.logical_and(est < 0.0, en_ref[...][None, :])

    # -- base-precision path (CU): int8 matmul + BN + residual + ReLU -----
    full = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    ).astype(jnp.float32) * dq_ref[0]
    relu_in = full * scale_ref[...][None, :] + shift_ref[...][None, :] + res_ref[...]
    y = jnp.maximum(relu_in, 0.0)
    y_ref[...] = jnp.where(skip, 0.0, y)
    skip_ref[...] = skip


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def mor_dense(
    x: jax.Array,
    w: jax.Array,
    m: jax.Array,
    b: jax.Array,
    bn_scale: jax.Array,
    bn_shift: jax.Array,
    residual: jax.Array,
    enabled: jax.Array,
    dq: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
):
    """Fused predicted dense layer. See module docstring and ref.mor_dense.

    x (M,K) int8 · w (K,N) int8; m/b/bn_scale/bn_shift/enabled are (N,)
    per-neuron parameters; residual is (M,N) float32; dq is a scalar
    dequantization factor (float_value = dq * int32_dot).
    Returns (y (M,N) float32, skipped (M,N) bool).
    """
    mdim, k = x.shape
    k2, n = w.shape
    assert k == k2
    bm_ = min(bm, _ceil(mdim, 8))
    bn_ = min(bn, _ceil(n, 8))
    pm, pn = (-mdim) % bm_, (-n) % bn_

    xp = jnp.pad(x, ((0, pm), (0, 0)))
    wp = jnp.pad(w, ((0, 0), (0, pn)))
    mp = jnp.pad(m, (0, pn))
    bp = jnp.pad(b, (0, pn))
    scp = jnp.pad(bn_scale, (0, pn))
    shp = jnp.pad(bn_shift, (0, pn))
    rp = jnp.pad(residual, ((0, pm), (0, pn)))
    enp = jnp.pad(enabled, (0, pn))  # pads with False: padded neurons never skip
    dqv = jnp.asarray(dq, jnp.float32).reshape(1)

    grid = (xp.shape[0] // bm_, wp.shape[1] // bn_)
    y, skip = pl.pallas_call(
        _mor_dense_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn_), lambda i, j: (0, j)),
            pl.BlockSpec((bn_,), lambda i, j: (j,)),
            pl.BlockSpec((bn_,), lambda i, j: (j,)),
            pl.BlockSpec((bn_,), lambda i, j: (j,)),
            pl.BlockSpec((bn_,), lambda i, j: (j,)),
            pl.BlockSpec((bm_, bn_), lambda i, j: (i, j)),
            pl.BlockSpec((bn_,), lambda i, j: (j,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bm_, bn_), lambda i, j: (i, j)),
            pl.BlockSpec((bm_, bn_), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]), jnp.float32),
            jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]), jnp.bool_),
        ],
        interpret=True,
    )(xp, wp, mp, bp, scp, shp, rp, enp, dqv)
    return y[:mdim, :n], skip[:mdim, :n]


def _ceil(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult


def vmem_bytes(bm: int, bn: int, k: int) -> int:
    """Working set: int8 x-slab + int8 w-slab (x2 for ±1 copies), f32 acc x2,
    (N,) params x5, residual tile."""
    return 2 * (bm * k + k * bn) + 4 * bm * bn * 3 + 4 * bn * 5
