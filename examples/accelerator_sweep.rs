//! Domain example: hardware design-space exploration on the cycle-level
//! accelerator model — sweep CU count and DRAM port width and watch where
//! the MoR advantage grows (memory-bound) or shrinks (compute-bound).
use anyhow::Result;
use mor::config::Config;
use mor::model::Artifacts;
use mor::predictor::RunOpts;
use mor::session::Session;
use mor::sim::Simulator;
use mor::util::bench::Table;

fn main() -> Result<()> {
    let dir = std::env::var("MOR_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let arts = Artifacts::load(&dir, "cnn10")?;
    let session = Session::from_artifacts(&arts, Default::default()).with_opts(
        RunOpts { oracle: false, collect_trace: true, ..Default::default() }.parallel(),
    );
    let trace = session.run_sample(arts.data.test_sample(0)).traces;

    let mut t = Table::new(
        "design-space sweep (cnn10): MoR speedup across CU count x DRAM port",
        &["num_cus", "port_bytes", "base_cycles", "mor_cycles", "speedup"],
    );
    for num_cus in [4usize, 8, 16] {
        for port in [4u64, 8, 16] {
            let mut cfg = Config::default();
            cfg.accel.num_cus = num_cus;
            cfg.dram.port_bytes = port;
            let sim = Simulator::new(cfg);
            let b = sim.simulate_sample(&arts.model, None, None);
            let m = sim.simulate_sample(&arts.model, session.policy(), Some(&trace));
            t.row(&[
                num_cus.to_string(),
                port.to_string(),
                b.cycles.to_string(),
                m.cycles.to_string(),
                format!("{:.3}", b.cycles as f64 / m.cycles as f64),
            ]);
        }
    }
    t.print();
    Ok(())
}
