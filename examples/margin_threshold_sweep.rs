//! Operating-point exploration: sweeps the skip-confidence margin (our
//! extension over the paper's raw rule) x the auto-chosen correlation
//! threshold, reporting the savings/accuracy frontier per model.
use mor::config::PredictorConfig;
use mor::predictor::{choose_threshold, MorRun};
use mor::session::Session;
use mor::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("MOR_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let mut t = Table::new(
        "margin x threshold frontier (256 test samples)",
        &["model", "margin_sigmas", "auto_T", "ops_saved_pct", "accuracy_loss_pp", "incorrect_zero_pct"],
    );
    for name in mor::MODELS {
        let a = mor::model::Artifacts::load(&dir, name)?;
        let base = MorRun::evaluate(&a, &Session::build(&a.model).finish(), 256);
        for margin in [0.0f32, 0.25, 0.5, 1.0, 2.0] {
            let cfg0 = PredictorConfig { margin_sigmas: margin, ..Default::default() };
            let thr = choose_threshold(&a, &cfg0, 3.2, 32);
            let sess =
                Session::from_artifacts(&a, PredictorConfig { threshold: thr, ..cfg0 });
            let s = MorRun::evaluate(&a, &sess, 256);
            t.row(&[
                name.to_string(),
                format!("{margin}"),
                format!("{thr}"),
                format!("{:.1}", s.ops.macs_saved_frac() * 100.0),
                format!("{:+.2}", (base.accuracy - s.accuracy) * 100.0),
                format!("{:.2}", s.pred.frac(s.pred.incorrect_zero) * 100.0),
            ]);
        }
    }
    t.print();
    Ok(())
}
