//! Quickstart: load a model bundle, run Mixture-of-Rookies inference on a
//! few samples, print savings and prediction quality.
//!
//!     make artifacts && cargo run --release --example quickstart
use anyhow::Result;
use mor::model::Artifacts;
use mor::predictor::MorRun;
use mor::session::Session;

fn main() -> Result<()> {
    let dir = std::env::var("MOR_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let arts = Artifacts::load(&dir, "tds")?;
    println!(
        "loaded {}: {:?} input, {:.1}M MACs/sample, int8 top-1 {:.1}%",
        arts.meta.name,
        arts.meta.input_shape,
        arts.meta.macs_per_sample as f64 / 1e6,
        arts.meta.int8_accuracy * 100.0
    );

    // baseline (no predictor) vs Mixture-of-Rookies: one Session facade,
    // the dense variant shares the model and prepacked weights
    let session = Session::build(&arts.model)
        .params(&arts.predictor)
        .predictor("mor")?
        .finish();
    let base = MorRun::evaluate(&arts, &session.with_policy(None), 64);
    let mor = MorRun::evaluate(&arts, &session, 64);

    println!("baseline accuracy: {:.1}%", base.accuracy * 100.0);
    println!(
        "MoR accuracy:      {:.1}%  (Δ {:+.2} pp)",
        mor.accuracy * 100.0,
        (mor.accuracy - base.accuracy) * 100.0
    );
    println!(
        "computations avoided: {:.1}% of MACs, {:.1} KB of weight traffic per sample",
        mor.ops.macs_saved_frac() * 100.0,
        mor.ops.weight_bytes_saved as f64 / 64.0 / 1024.0
    );
    let p = &mor.pred;
    println!(
        "outcomes: correct-zero {:.1}% | incorrect-zero {:.2}% | correct-nonzero {:.1}%",
        p.frac(p.correct_zero) * 100.0,
        p.frac(p.incorrect_zero) * 100.0,
        p.frac(p.correct_nonzero) * 100.0
    );
    Ok(())
}
