//! END-TO-END driver (DESIGN.md: the run recorded in EXPERIMENTS.md):
//! exercises all layers of the stack on a real small workload and proves
//! they compose:
//!
//!   1. artifacts (L1 Pallas kernels + L2 JAX models, AOT-lowered) load;
//!   2. the PJRT runtime executes a model's HLO and its logits agree with
//!      the rust functional engine on real test samples;
//!   3. the MoR predictor runs on all four models: accuracy loss < 1 pp
//!      with real computation savings;
//!   4. the cycle-level accelerator simulates baseline vs MoR (speedup);
//!   5. the serving coordinator sustains a request stream with the
//!      predictor enabled.
use anyhow::{ensure, Result};
use mor::config::{Config, PredictorConfig};
use mor::coordinator::{serve, Backend, ServeOpts};
use mor::model::Artifacts;
use mor::predictor::{argmax, exec, MorRun, RunOpts};
use mor::runtime::Runtime;
use mor::session::Session;
use mor::sim::Simulator;
use mor::workload::RequestStream;

fn main() -> Result<()> {
    let dir = std::env::var("MOR_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    println!("=== E2E full-system driver ===");

    // -- stage 1+2: PJRT runtime vs functional engine ----------------------
    let rt = Runtime::cpu()?;
    println!("[1] PJRT platform: {}", rt.platform());
    let arts = Artifacts::load(&dir, "tds")?;
    let exe = rt.load_hlo(Artifacts::hlo_path(&dir, "tds"), arts.meta.input_shape)?;
    let mut agree = 0;
    let n_check = 16;
    for i in 0..n_check {
        let sample = arts.data.test_sample(i);
        let pjrt_logits = exe.forward(sample)?;
        let eng = exec::run_sample(
            &arts.model,
            None,
            sample,
            RunOpts { oracle: false, collect_trace: false, ..Default::default() },
        );
        if argmax(&pjrt_logits) == argmax(&eng.logits) {
            agree += 1;
        }
        let md: f32 = pjrt_logits
            .iter()
            .zip(&eng.logits)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        ensure!(md < 1e-2, "PJRT vs engine logits diverge: max |Δ| = {md}");
    }
    println!("[2] PJRT == engine on {agree}/{n_check} argmax, logits allclose ✓");

    // -- stage 3: MoR on the full zoo --------------------------------------
    let mut total_saved = 0.0;
    for name in mor::MODELS {
        let a = Artifacts::load(&dir, name)?;
        // per-DNN threshold from training data, as in the paper (Sec 3.2.1)
        let thr = mor::predictor::choose_threshold(&a, &PredictorConfig::default(), 3.2, 32);
        let sess = Session::from_artifacts(
            &a,
            PredictorConfig { threshold: thr, ..Default::default() },
        );
        let base = MorRun::evaluate(&a, &sess.with_policy(None), 96);
        let s = MorRun::evaluate(&a, &sess, 96);
        let loss_pp = (base.accuracy - s.accuracy) * 100.0;
        let saved = s.ops.macs_saved_frac() * 100.0;
        total_saved += saved;
        println!(
            "[3] {name:<12} T={thr} saved {saved:>5.1}% MACs | accuracy {:.1}% → {:.1}% (Δ {loss_pp:+.2} pp)",
            base.accuracy * 100.0,
            s.accuracy * 100.0
        );
        ensure!(loss_pp < 1.5, "{name}: accuracy loss {loss_pp} pp exceeds budget");
        ensure!(saved > 0.0, "{name}: no savings");
    }
    ensure!(total_saved > 0.0);

    // -- stage 4: cycle-level accelerator ----------------------------------
    let cfg = Config::default();
    let a = Artifacts::load(&dir, "cnn10")?;
    let thr = mor::predictor::choose_threshold(&a, &cfg.predictor, 3.2, 32);
    let sess = Session::from_artifacts(
        &a,
        PredictorConfig { threshold: thr, ..cfg.predictor.clone() },
    )
    .with_opts(RunOpts { oracle: false, collect_trace: true, ..Default::default() });
    let sim = Simulator::new(cfg.clone());
    let tr = sess.run_sample(a.data.test_sample(0)).traces;
    let b = sim.simulate_sample(&a.model, None, None);
    let m = sim.simulate_sample(&a.model, sess.policy(), Some(&tr));
    println!(
        "[4] cnn10 accelerator: {} → {} cycles (speedup {:.3}x) | DRAM {} → {} KB",
        b.cycles, m.cycles,
        b.cycles as f64 / m.cycles as f64,
        b.dram_bytes / 1024, m.dram_bytes / 1024
    );
    ensure!(m.cycles <= b.cycles, "MoR made the accelerator slower");

    // -- stage 5: serving ---------------------------------------------------
    let arts = Artifacts::load(&dir, "tds")?;
    let session = Session::from_artifacts(&arts, PredictorConfig::default());
    let mut stream = RequestStream::new(200.0, arts.data.n_test(), 11);
    let requests = stream.generate(2.0);
    let n_req = requests.len();
    let rep = serve(
        &arts,
        &session,
        Backend::Engine,
        requests,
        &dir,
        ServeOpts { workers: 4, max_batch: 8, ..Default::default() },
    )?;
    rep.print("e2e");
    ensure!(rep.completed == n_req && rep.dropped == 0, "dropped requests");

    println!("=== E2E OK: all layers compose ===");
    Ok(())
}
