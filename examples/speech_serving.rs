//! Domain example: low-latency speech recognition serving (the paper's
//! motivating workload — TDS frame-by-frame inference on-edge).
//!
//! Streams Poisson-arriving utterance requests through the coordinator on
//! the functional engine backend with the MoR predictor enabled, then
//! compares against the no-predictor baseline.
use anyhow::Result;
use mor::config::PredictorConfig;
use mor::coordinator::{serve, Backend};
use mor::model::Artifacts;
use mor::predictor::MorPolicy;
use mor::workload::RequestStream;

fn main() -> Result<()> {
    let dir = std::env::var("MOR_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let arts = Artifacts::load(&dir, "tds")?;
    let rps = 300.0;
    let duration = 3.0;
    let workers = 4;

    let mut stream = RequestStream::new(rps, arts.data.n_test(), 7);
    let requests = stream.generate(duration);
    println!("speech serving: {} requests at {rps} rps over {duration}s, {workers} workers", requests.len());

    let policy = MorPolicy::new(&arts.model, &arts.predictor, PredictorConfig::default());
    let rep = serve(
        &arts, Some(policy), Backend::Engine, workers, requests.clone(), &dir, 1.0, 1,
    )?;
    rep.print("tds+MoR");

    let rep0 = serve(&arts, None, Backend::Engine, workers, requests, &dir, 1.0, 1)?;
    rep0.print("tds baseline");

    println!(
        "service-time speedup from skipping: {:.2}x",
        rep0.mean_service_ms / rep.mean_service_ms.max(1e-9)
    );
    Ok(())
}
