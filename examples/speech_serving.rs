//! Domain example: low-latency speech recognition serving (the paper's
//! motivating workload — TDS frame-by-frame inference on-edge).
//!
//! Streams bursty utterance-shaped requests through the coordinator on
//! the functional engine backend with the MoR predictor enabled, then
//! compares against the no-predictor baseline and shows what micro-
//! batching does to throughput and tail latency.
use anyhow::Result;
use mor::coordinator::{serve, Backend, ServeOpts};
use mor::model::Artifacts;
use mor::session::Session;
use mor::workload::{Arrival, RequestStream};

fn main() -> Result<()> {
    let dir = std::env::var("MOR_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let arts = Artifacts::load(&dir, "tds")?;
    let rps = 300.0;
    let duration = 3.0;
    let opts = ServeOpts { workers: 4, ..Default::default() };

    // speech traffic is bursty: utterances arrive in clumps, not as a
    // memoryless stream — exactly the shape micro-batching absorbs
    let arrival = Arrival::from_cli("bursty", rps)?;
    let mut stream = RequestStream::with_arrival(arrival, arts.data.n_test(), 7);
    let requests = stream.generate(duration);
    println!(
        "speech serving: {} bursty requests (avg {rps} rps) over {duration}s, {} workers",
        requests.len(),
        opts.workers
    );

    let session = Session::build(&arts.model)
        .params(&arts.predictor)
        .predictor("mor")?
        .finish();
    let rep = serve(&arts, &session, Backend::Engine, requests.clone(), &dir, opts)?;
    rep.print("tds+MoR");

    let dense = session.with_policy(None);
    let rep0 = serve(&arts, &dense, Backend::Engine, requests.clone(), &dir, opts)?;
    rep0.print("tds baseline");

    println!(
        "service-time speedup from skipping: {:.2}x",
        rep0.mean_service_ms / rep.mean_service_ms.max(1e-9)
    );

    // batching: same trace, micro-batches of up to 8 requests share one
    // predict-then-evaluate pass per row tile
    let batched = ServeOpts { max_batch: 8, batch_wait_us: 2_000, ..opts };
    let repb = serve(&arts, &session, Backend::Engine, requests, &dir, batched)?;
    repb.print("tds+MoR, batch<=8");
    println!(
        "batching: occupancy {:.2} | p99 {:.2} → {:.2} ms | {:.0} → {:.0} rps",
        repb.batch_occupancy, rep.p99_ms, repb.p99_ms, rep.throughput_rps, repb.throughput_rps
    );
    Ok(())
}
