#!/usr/bin/env bash
# Schema + conservation checks for the committed BENCH_*.json snapshots.
#
# BENCH_serving.json — the serving_tier block is the machine-readable
# contract of the sharded tier (EXPERIMENTS.md §Tier): this script fails
# CI if the block goes missing, loses its per-tenant/per-model
# breakdowns, or stops conserving requests (completed + dropped + shed
# == submitted, per group and in total).
#
# BENCH_hotpaths.json — the kernels block is the cross-ISA contract
# (EXPERIMENTS.md §Tune): per-ISA dot GMAC/s over the density grid and
# the tuned-vs-default forward, all positive and keyed by known tiers.
#
# Both files must carry an `_provenance` object naming the detected and
# active ISA tiers plus the 16-hex-digit tune-profile hash, so perf
# trajectories are only diffed between like hosts. Works on both the
# hand-authored snapshots and regenerated bench output.
#
# Usage: bash tools/bench_schema.sh [BENCH_serving.json] [BENCH_hotpaths.json]
set -euo pipefail

SERVING="${1:-BENCH_serving.json}"
HOTPATHS="${2:-BENCH_hotpaths.json}"

python3 - "$SERVING" "$HOTPATHS" <<'EOF'
import json, re, sys

serving_path, hotpaths_path = sys.argv[1], sys.argv[2]
errors = []

def need(obj, key, types, where):
    if key not in obj:
        errors.append(f"{where}: missing key '{key}'")
        return None
    if not isinstance(obj[key], types):
        errors.append(f"{where}: '{key}' has type {type(obj[key]).__name__}")
        return None
    return obj[key]

num = (int, float)

ISA_TIERS = ("scalar", "neon", "avx2", "avx512vnni")

def check_provenance(doc, path):
    prov = need(doc, "_provenance", dict, path)
    if prov is None:
        return
    where = f"{path}:_provenance"
    for key in ("isa_detected", "isa_active"):
        tier = need(prov, key, str, where)
        if tier is not None and tier not in ISA_TIERS:
            errors.append(f"{where}: '{key}' = '{tier}' is not an ISA tier")
    h = need(prov, "tune_profile_hash", str, where)
    if h is not None and not re.fullmatch(r"[0-9a-f]{16}", h):
        errors.append(f"{where}: tune_profile_hash '{h}' is not 16 hex digits")

# ---- BENCH_serving.json ------------------------------------------------
with open(serving_path) as f:
    doc = json.load(f)
check_provenance(doc, serving_path)

tier = need(doc, "serving_tier", dict, serving_path)
if tier is not None:
    where = "serving_tier"
    for key in ("deadline_ms", "throughput_rps", "goodput_rps", "p50_ms", "p99_ms"):
        need(tier, key, num, where)
    for key in ("submitted", "completed", "dropped", "shed",
                "shed_admission", "shed_expired", "max_queue_depth"):
        need(tier, key, int, where)

    if not errors:
        if tier["completed"] + tier["dropped"] + tier["shed"] != tier["submitted"]:
            errors.append(
                f"{where}: conservation broken: {tier['completed']} completed + "
                f"{tier['dropped']} dropped + {tier['shed']} shed != "
                f"{tier['submitted']} submitted")
        if tier["shed_admission"] + tier["shed_expired"] != tier["shed"]:
            errors.append(f"{where}: shed_admission + shed_expired != shed")

    group_keys = ("name", "submitted", "completed", "shed",
                  "goodput_rps", "p50_ms", "p99_ms")
    for block in ("per_tenant", "per_model"):
        groups = need(tier, block, list, where)
        if groups is None:
            continue
        if not groups:
            errors.append(f"{where}.{block}: empty — the breakdown is the point")
            continue
        for i, g in enumerate(groups):
            gw = f"{where}.{block}[{i}]"
            if not isinstance(g, dict):
                errors.append(f"{gw}: not an object")
                continue
            for key in group_keys:
                need(g, key, str if key == "name" else num, gw)
            if all(k in g for k in ("submitted", "completed", "shed")):
                if g["completed"] + g["shed"] != g["submitted"]:
                    errors.append(f"{gw}: completed + shed != submitted")
        # error drops are not attributed to groups, so group completions
        # and sheds must sum exactly to the tier totals
        if all(isinstance(g, dict) for g in groups):
            for key in ("completed", "shed"):
                if key in tier and all(key in g for g in groups):
                    total = sum(g[key] for g in groups)
                    if total != tier[key]:
                        errors.append(
                            f"{where}.{block}: sum of {key} is {total}, "
                            f"tier total is {tier[key]}")

# ---- BENCH_hotpaths.json -----------------------------------------------
with open(hotpaths_path) as f:
    hdoc = json.load(f)
check_provenance(hdoc, hotpaths_path)

kernels = need(hdoc, "kernels", dict, hotpaths_path)
n_tiers = 0
if kernels is not None:
    where = f"{hotpaths_path}:kernels"
    dots = need(kernels, "dot_gmacs", dict, where)
    if dots is not None:
        if not dots:
            errors.append(f"{where}.dot_gmacs: empty — at least scalar must be present")
        if "scalar" not in dots:
            errors.append(f"{where}.dot_gmacs: missing the 'scalar' baseline tier")
        for tier_name, grid in dots.items():
            gw = f"{where}.dot_gmacs.{tier_name}"
            if tier_name not in ISA_TIERS:
                errors.append(f"{gw}: not an ISA tier")
                continue
            n_tiers += 1
            if not isinstance(grid, dict):
                errors.append(f"{gw}: not an object")
                continue
            for d in ("10", "25", "50", "100"):
                v = need(grid, d, num, gw)
                if v is not None and v <= 0:
                    errors.append(f"{gw}.{d}: GMAC/s must be positive, got {v}")
    h = need(kernels, "tuned_profile_hash", str, where)
    if h is not None and not re.fullmatch(r"[0-9a-f]{16}", h):
        errors.append(f"{where}: tuned_profile_hash '{h}' is not 16 hex digits")
    fwd = need(kernels, "forward_ms", dict, where)
    if fwd is not None:
        for key in ("default", "tuned"):
            v = need(fwd, key, num, where + ".forward_ms")
            if v is not None and v <= 0:
                errors.append(f"{where}.forward_ms.{key}: must be positive, got {v}")

if errors:
    print("bench schema check FAILED")
    for e in errors:
        print(f"  - {e}")
    sys.exit(1)
print(f"{serving_path}: serving-tier schema OK "
      f"({len(tier['per_tenant'])} tenants, {len(tier['per_model'])} models)")
print(f"{hotpaths_path}: kernels schema OK ({n_tiers} ISA tier(s))")
EOF
