#!/usr/bin/env bash
# Schema + conservation check for BENCH_serving.json.
#
# The serving_tier block is the machine-readable contract of the
# sharded tier (EXPERIMENTS.md §Tier): this script fails CI if the
# block goes missing, loses its per-tenant/per-model breakdowns, or
# stops conserving requests (completed + dropped + shed == submitted,
# per group and in total). Works on both the hand-authored snapshot and
# regenerated bench output — conservation is exact in either.
#
# Usage: bash tools/bench_schema.sh [path/to/BENCH_serving.json]
set -euo pipefail

FILE="${1:-BENCH_serving.json}"

python3 - "$FILE" <<'EOF'
import json, sys

path = sys.argv[1]
errors = []

with open(path) as f:
    doc = json.load(f)

def need(obj, key, types, where):
    if key not in obj:
        errors.append(f"{where}: missing key '{key}'")
        return None
    if not isinstance(obj[key], types):
        errors.append(f"{where}: '{key}' has type {type(obj[key]).__name__}")
        return None
    return obj[key]

num = (int, float)

tier = need(doc, "serving_tier", dict, path)
if tier is not None:
    where = "serving_tier"
    for key in ("deadline_ms", "throughput_rps", "goodput_rps", "p50_ms", "p99_ms"):
        need(tier, key, num, where)
    for key in ("submitted", "completed", "dropped", "shed",
                "shed_admission", "shed_expired", "max_queue_depth"):
        need(tier, key, int, where)

    if not errors:
        if tier["completed"] + tier["dropped"] + tier["shed"] != tier["submitted"]:
            errors.append(
                f"{where}: conservation broken: {tier['completed']} completed + "
                f"{tier['dropped']} dropped + {tier['shed']} shed != "
                f"{tier['submitted']} submitted")
        if tier["shed_admission"] + tier["shed_expired"] != tier["shed"]:
            errors.append(f"{where}: shed_admission + shed_expired != shed")

    group_keys = ("name", "submitted", "completed", "shed",
                  "goodput_rps", "p50_ms", "p99_ms")
    for block in ("per_tenant", "per_model"):
        groups = need(tier, block, list, where)
        if groups is None:
            continue
        if not groups:
            errors.append(f"{where}.{block}: empty — the breakdown is the point")
            continue
        for i, g in enumerate(groups):
            gw = f"{where}.{block}[{i}]"
            if not isinstance(g, dict):
                errors.append(f"{gw}: not an object")
                continue
            for key in group_keys:
                need(g, key, str if key == "name" else num, gw)
            if all(k in g for k in ("submitted", "completed", "shed")):
                if g["completed"] + g["shed"] != g["submitted"]:
                    errors.append(f"{gw}: completed + shed != submitted")
        # error drops are not attributed to groups, so group completions
        # and sheds must sum exactly to the tier totals
        if all(isinstance(g, dict) for g in groups):
            for key in ("completed", "shed"):
                if key in tier and all(key in g for g in groups):
                    total = sum(g[key] for g in groups)
                    if total != tier[key]:
                        errors.append(
                            f"{where}.{block}: sum of {key} is {total}, "
                            f"tier total is {tier[key]}")

if errors:
    print(f"{path}: serving-tier schema check FAILED")
    for e in errors:
        print(f"  - {e}")
    sys.exit(1)
print(f"{path}: serving-tier schema OK "
      f"({len(tier['per_tenant'])} tenants, {len(tier['per_model'])} models)")
EOF
