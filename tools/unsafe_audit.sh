#!/usr/bin/env bash
# Unsafe-soundness audit: every `unsafe` in the rust crate must carry an
# adjacent justification, so the safety argument lives next to the code
# it protects and a reviewer never has to reconstruct it.
#
#   * `unsafe fn` / `unsafe impl` / `unsafe trait` declarations need a
#     `# Safety` doc section or a `// SAFETY:` comment within the
#     preceding 20 lines (doc contracts sit above the signature, past
#     attributes and other doc lines).
#   * every other `unsafe` occurrence (an `unsafe { ... }` block) needs
#     a `// SAFETY:` comment in the contiguous comment block directly
#     above it (multi-line SAFETY comments count in full).
#
# `unsafe` is matched with explicit word boundaries (POSIX character
# classes — portable across mawk/gawk, unlike `\<`), so identifiers like
# `unsafe_op_in_unsafe_fn` (the crate-root lint) don't count; comment
# lines are stripped before matching so prose about unsafety doesn't
# either. Exits non-zero listing every unjustified site, so the audit
# fails CI fast. The crate-root `#![deny(unsafe_op_in_unsafe_fn)]`
# complements this: rustc proves every unsafe operation is inside a
# block, this script proves every block argues why it is sound.
#
# Usage: tools/unsafe_audit.sh              # audits rust/src, rust/tests,
#                                           # rust/benches (missing roots
#                                           # are skipped with a note)
#        tools/unsafe_audit.sh DIR...       # audits the given trees
#        tools/unsafe_audit.sh --self-test  # red/green check of the audit
#                                           # itself over fixture trees
set -u

# --self-test: prove the audit both accepts a justified tree and rejects
# an unjustified one, across all three default root kinds, so a silent
# regression in the awk matcher can't greenwash CI.
if [ "${1:-}" = "--self-test" ]; then
  tmp=$(mktemp -d)
  trap 'rm -rf "$tmp"' EXIT
  mkdir -p "$tmp/good/src" "$tmp/good/tests" "$tmp/good/benches" "$tmp/bad/tests"
  for d in src tests benches; do
    cat > "$tmp/good/$d/fixture.rs" <<'EOF'
fn main() {
    // SAFETY: the pointer is derived from a live reference above.
    unsafe { std::ptr::read(&0u8) };
}
EOF
  done
  cat > "$tmp/bad/tests/fixture.rs" <<'EOF'
fn main() {
    unsafe { std::ptr::read(&0u8) };
}
EOF
  if ! bash "$0" "$tmp/good/src" "$tmp/good/tests" "$tmp/good/benches" >/dev/null 2>&1; then
    echo "unsafe_audit self-test: FAILED (justified fixture tree was rejected)" >&2
    exit 1
  fi
  if bash "$0" "$tmp/bad/tests" >/dev/null 2>&1; then
    echo "unsafe_audit self-test: FAILED (unjustified unsafe in a tests root passed)" >&2
    exit 1
  fi
  echo "unsafe_audit self-test: ok (green tree passes, red tree fails)"
  exit 0
fi

# default roots: the crate sources AND the test/bench trees — an unsafe
# block smuggled into a test must argue its soundness like any other.
# ${@:-...} would collapse the default into one word, so branch instead.
if [ "$#" -eq 0 ]; then
  roots=()
  for d in rust/src rust/tests rust/benches; do
    if [ -e "$d" ]; then
      roots+=("$d")
    else
      echo "unsafe_audit: skipping absent default root: $d" >&2
    fi
  done
else
  roots=("$@")
fi
status=0
found=0
for root in "${roots[@]}"; do
  if [ ! -e "$root" ]; then
    echo "unsafe_audit: no such path: $root" >&2
    status=1
    continue
  fi
  while IFS= read -r file; do
    found=1
    out=$(awk '
      {
        raw = $0
        line = raw
        sub(/\/\/.*$/, "", line)          # strip // comments before matching
        safety[NR] = (raw ~ /SAFETY:/ || raw ~ /# Safety/)
        comment[NR] = (raw ~ /^[ \t]*\/\//)
        if (line ~ /(^|[^A-Za-z0-9_])unsafe($|[^A-Za-z0-9_])/) {
          decl = (line ~ /(^|[^A-Za-z0-9_])unsafe[ \t]+(fn|impl|trait)($|[^A-Za-z0-9_])/)
          ok = 0
          if (decl) {
            # doc contract above the signature, past attributes/doc lines
            for (i = NR - 20; i < NR; i++)
              if (i in safety && safety[i]) ok = 1
          } else {
            # the contiguous comment block directly above the unsafe block
            for (i = NR - 1; i >= 1 && comment[i]; i--)
              if (safety[i]) ok = 1
            if (safety[NR]) ok = 1
          }
          if (!ok)
            printf "%s:%d: unsafe without adjacent %s: %s\n", FILENAME, NR, \
                   (decl ? "# Safety contract or SAFETY: comment" : "SAFETY: comment"), raw
        }
      }
    ' "$file")
    if [ -n "$out" ]; then
      echo "$out" >&2
      status=1
    fi
  done < <(find "$root" -name '*.rs' -type f | sort)
done
if [ "$found" -eq 0 ]; then
  echo "unsafe_audit: no rust files found under: ${roots[*]}" >&2
  exit 1
fi
if [ "$status" -eq 0 ]; then
  echo "unsafe_audit: every unsafe site is justified in: ${roots[*]}"
fi
exit "$status"
