#!/usr/bin/env bash
# Markdown link check: every relative link in the given files must point
# at an existing file or directory (resolved against the markdown file's
# own directory). External links (http/https/mailto) and same-document
# anchors are skipped. Exits non-zero listing every rotten link, so doc
# rot fails CI fast.
#
# Usage: tools/linkcheck.sh README.md EXPERIMENTS.md ROADMAP.md
set -u
status=0
for f in "$@"; do
  if [ ! -f "$f" ]; then
    echo "linkcheck: no such file: $f" >&2
    status=1
    continue
  fi
  dir=$(dirname "$f")
  while IFS= read -r link; do
    case "$link" in
      http://* | https://* | mailto:* | '#'*) continue ;;
    esac
    path="${link%%#*}"
    # drop an optional markdown title: [text](FILE.md "Title")
    path="${path%% \"*}"
    # and angle-bracketed targets: [text](<FILE.md>)
    path="${path#<}"
    path="${path%>}"
    [ -z "$path" ] && continue
    if [ ! -e "$dir/$path" ]; then
      echo "rotten link in $f: ($link) -> $dir/$path does not exist" >&2
      status=1
    fi
  done < <(grep -o '\[[^]]*\]([^)]*)' "$f" | sed 's/^.*(\(.*\))$/\1/')
done
if [ "$status" -eq 0 ]; then
  echo "linkcheck: all relative links resolve in: $*"
fi
exit "$status"
